"""Property-based invariant contract for ALL planners (single-node + cluster).

Uses the hypothesis compat shim, so the sweep runs (fixed-seed) even where
hypothesis is not installed.  The contract (also documented in
``repro/cluster/__init__.py``):

  * a plan reported feasible predicts completion inside the deadline,
  * every planned frequency is a state of the governing ladder,
  * DV-DVFS busy energy never exceeds DVO (all-f_max) on the same blocks,
  * the roofline planner never pays time for memory-bound down-clocks.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (DEFAULT_LADDER, BlockInfo, FrequencyLadder,
                        RooflineTimeModel, block_time, plan_dvfs, plan_dvo,
                        simulate)
from repro.cluster import NodeSpec, plan_cluster, plan_independent

DEEP_LADDER = FrequencyLadder(
    states=tuple(round(f, 2) for f in np.arange(0.35, 1.001, 0.05)))
COARSE_LADDER = FrequencyLadder(states=(0.5, 0.75, 1.0))
LADDERS = {"default": DEFAULT_LADDER, "deep": DEEP_LADDER,
           "coarse": COARSE_LADDER}


def _blocks(costs):
    return [BlockInfo(i, float(c)) for i, c in enumerate(costs)]


def _in_ladder(freq, ladder):
    return any(abs(freq - f) < 1e-9 for f in ladder.states)


@settings(max_examples=40, deadline=None)
@given(
    costs=st.lists(st.floats(0.05, 30.0), min_size=1, max_size=32),
    slack=st.floats(0.0, 1.2),
    planner=st.sampled_from(["paper", "global"]),
    ladder_name=st.sampled_from(["default", "deep", "coarse"]),
)
def test_single_node_contract(costs, slack, planner, ladder_name):
    ladder = LADDERS[ladder_name]
    blocks = _blocks(costs)
    deadline = sum(costs) * (1.0 + slack) + 1e-6
    plan = plan_dvfs(blocks, deadline, planner=planner, ladder=ladder)
    # feasible => predicted completion inside the deadline
    if plan.feasible:
        assert plan.pred_total_time <= deadline + 1e-9
    # frequencies come from the governing ladder
    for bp in plan.blocks:
        assert _in_ladder(bp.rel_freq, ladder)
    # DVFS energy never above DVO on identical blocks
    dvo = plan_dvo(blocks, deadline)
    assert plan.pred_total_energy <= dvo.pred_total_energy * (1 + 1e-9)
    # and the simulated (truth == estimate) run agrees
    rep = simulate(plan, blocks)
    rep_dvo = simulate(dvo, blocks)
    assert rep.total_energy_j <= rep_dvo.total_energy_j * (1 + 1e-9)


@settings(max_examples=25, deadline=None)
@given(
    flops=st.floats(1e9, 1e13),
    hbm_bytes=st.floats(1e9, 50e9),
    n_blocks=st.integers(1, 12),
)
def test_roofline_never_pays_time_for_memory_bound_downclock(
        flops, hbm_bytes, n_blocks):
    """Any roofline down-clock to a state at or above the zero-cost frequency
    must leave the block's predicted time exactly at its f_max time."""
    rt = RooflineTimeModel.from_counts(flops=flops, hbm_bytes=hbm_bytes,
                                       coll_bytes=0, chips=1)
    blocks = [BlockInfo(i, rt.time_at(1.0), roofline=rt)
              for i in range(n_blocks)]
    t_fmax = sum(b.est_time_fmax for b in blocks)
    plan = plan_dvfs(blocks, t_fmax * 1.0001, planner="roofline",
                     error_margin=0.0)
    f_star = rt.zero_cost_freq()
    for b, bp in zip(blocks, plan.blocks):
        if bp.rel_freq >= f_star - 1e-9:
            assert bp.pred_time_s == pytest.approx(block_time(b, 1.0),
                                                   rel=1e-9)
    # with NO deadline slack the whole plan must be time-neutral
    assert plan.pred_total_time <= t_fmax * 1.0001 + 1e-9
    dvo = plan_dvo(blocks, t_fmax * 1.0001)
    assert plan.pred_total_energy <= dvo.pred_total_energy * (1 + 1e-9)


@settings(max_examples=25, deadline=None)
@given(
    costs=st.lists(st.floats(0.05, 30.0), min_size=1, max_size=24),
    slack=st.floats(0.05, 1.5),
    n_nodes=st.integers(1, 5),
    assignment=st.sampled_from(["lpt", "round_robin"]),
)
def test_cluster_contract(costs, slack, n_nodes, assignment):
    """Cluster plans: per-node deadline feasibility, per-node ladder
    membership, energy never above the all-f_max cluster baseline."""
    speeds = (1.0, 0.7, 1.3, 0.85, 1.2)
    ladders = (DEFAULT_LADDER, DEEP_LADDER, COARSE_LADDER)
    blocks = _blocks(costs)
    nodes = [NodeSpec(f"n{k}", speed=speeds[k % len(speeds)],
                      ladder=ladders[k % len(ladders)])
             for k in range(n_nodes)]
    # deadline: slowest-single-node time x slack always admits SOME plan
    worst = sum(costs) / min(n.speed for n in nodes)
    deadline = worst * (1.0 + slack)
    plan = plan_cluster(blocks, nodes, deadline, assignment=assignment)
    assert plan.feasible
    total_dvo = 0.0
    for np_ in plan.node_plans:
        assert np_.pred_finish_s <= deadline + 1e-9
        for bp in np_.blocks:
            assert _in_ladder(bp.rel_freq, np_.node.ladder)
        total_dvo += sum(
            np_.node.block_energy(b, np_.node.block_time(b, 1.0), 1.0)
            for b in blocks if plan.assignment()[b.index] == np_.node.name)
    assert plan.pred_total_energy <= total_dvo * (1 + 1e-9)
    # every block is planned exactly once
    assert sorted(plan.assignment().keys()) == [b.index for b in blocks]


@settings(max_examples=15, deadline=None)
@given(
    costs=st.lists(st.floats(0.5, 20.0), min_size=3, max_size=24),
    n_nodes=st.integers(2, 4),
)
def test_independent_baseline_contract(costs, n_nodes):
    """The round-robin + per-node Algorithm 1 baseline obeys the same ladder
    and energy contract (it is a planner too, just an oblivious one)."""
    blocks = _blocks(costs)
    nodes = [NodeSpec(f"n{k}", speed=(1.0, 0.8, 1.2, 0.9)[k % 4])
             for k in range(n_nodes)]
    worst = sum(costs) / min(n.speed for n in nodes)
    plan = plan_independent(blocks, nodes, worst * 1.5)
    for np_ in plan.node_plans:
        for bp in np_.blocks:
            assert _in_ladder(bp.rel_freq, np_.node.ladder)
    assert sorted(plan.assignment().keys()) == [b.index for b in blocks]
