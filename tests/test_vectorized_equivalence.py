"""Vectorized hot path == loop reference, property-based.

The planner/sampler/kernel hot paths (``repro.core.scheduler``,
``repro.core.sampling``, ``repro.kernels.block_stats``) are array-level
rewrites of loop code that now lives in ``repro.core._reference`` (and
``plan_cluster_reference``).  This suite is the contract that lets the
references stay frozen: across random ladders, power models, rooflines,
deadlines, and assignments the vectorized implementations must produce
IDENTICAL plans (same frequencies, energies within 1e-9) and identical
sampling estimates.  Runs under the hypothesis compat shim, so the sweep
executes (fixed-seed) even where hypothesis is not installed.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (BlockInfo, FrequencyLadder, PowerModel,
                        RooflineTimeModel, plan_dvfs, plan_dvo,
                        sample_block_cost, sample_blocks)
from repro.core import _reference as ref
from repro.cluster import NodeSpec, plan_cluster
from repro.cluster.planner import plan_cluster_reference


def _ladder(rnd_states):
    """Random strictly-ascending ladder ending at exactly 1.0."""
    states = tuple(sorted(set(round(s, 3) for s in rnd_states
                              if 0.05 <= s <= 0.99))) + (1.0,)
    return FrequencyLadder(states=states)


def _blocks(costs, rooflines):
    out = []
    for i, (c, rf) in enumerate(zip(costs, rooflines)):
        roof = None
        if rf is not None:
            flops, hbm = rf
            roof = RooflineTimeModel.from_counts(flops=flops, hbm_bytes=hbm,
                                                 coll_bytes=0.0)
        out.append(BlockInfo(i, float(c), est_rel_halfwidth=0.01 * (i % 7),
                             util=0.4 + 0.05 * (i % 12), roofline=roof))
    return out


def _assert_plans_identical(p, q):
    assert p.feasible == q.feasible
    assert p.planner == q.planner
    assert len(p.blocks) == len(q.blocks)
    for a, b in zip(p.blocks, q.blocks):
        assert a.index == b.index
        assert a.rel_freq == b.rel_freq          # exactly: same ladder state
        assert abs(a.pred_time_s - b.pred_time_s) <= 1e-9
        assert abs(a.pred_energy_j - b.pred_energy_j) <= 1e-9
    assert p.pred_total_energy == pytest.approx(q.pred_total_energy, abs=1e-6)


@settings(max_examples=60, deadline=None)
@given(
    costs=st.lists(st.floats(0.05, 40.0), min_size=1, max_size=48),
    slack=st.floats(0.0, 1.6),
    planner=st.sampled_from(["paper", "global", "roofline"]),
    ladder_states=st.lists(st.floats(0.05, 0.99), min_size=1, max_size=14),
    p_full=st.floats(80.0, 400.0),
    p_idle=st.floats(1.0, 79.0),
    alpha=st.floats(0.8, 3.5),
    margin=st.floats(0.0, 0.25),
    adaptive=st.booleans(),
    roofline_every=st.integers(0, 3),
)
def test_plan_dvfs_matches_reference(costs, slack, planner, ladder_states,
                                     p_full, p_idle, alpha, margin, adaptive,
                                     roofline_every):
    ladder = _ladder(ladder_states)
    power = PowerModel(p_full=p_full, p_idle=p_idle, alpha=alpha)
    rooflines = [
        (1e9 * (1 + 37 * (i % 11)), 1e8 * (1 + 29 * (i % 13)))
        if (planner == "roofline" or
            (roofline_every and i % (roofline_every + 1) == 0)) else None
        for i in range(len(costs))
    ]
    blocks = _blocks(costs, rooflines)
    deadline = sum(costs) * (1.0 + slack) + 1e-6
    kw = dict(planner=planner, ladder=ladder, power=power,
              error_margin=margin, adaptive_margin=adaptive)
    _assert_plans_identical(plan_dvfs(blocks, deadline, **kw),
                            ref.plan_dvfs_reference(blocks, deadline, **kw))


@settings(max_examples=30, deadline=None)
@given(
    costs=st.lists(st.floats(0.1, 25.0), min_size=1, max_size=32),
    slack=st.floats(0.0, 1.5),
    n_nodes=st.integers(1, 5),
    assignment=st.sampled_from(["auto", "lpt", "pack", "round_robin"]),
    margin=st.floats(0.0, 0.2),
)
def test_plan_cluster_matches_reference(costs, slack, n_nodes, assignment,
                                        margin):
    speeds = (1.0, 0.7, 1.3, 0.85, 1.2)
    ladders = (FrequencyLadder(),
               FrequencyLadder(states=(0.5, 0.75, 1.0)),
               FrequencyLadder(states=tuple(
                   round(f, 2) for f in np.arange(0.35, 1.001, 0.05))))
    powers = (PowerModel(), PowerModel(p_full=95.0, p_idle=15.0, alpha=3.0),
              PowerModel(p_full=300.0, p_idle=40.0, alpha=1.6))
    nodes = [NodeSpec(f"n{k}", speed=speeds[k % 5], ladder=ladders[k % 3],
                      power=powers[k % 3]) for k in range(n_nodes)]
    blocks = _blocks(costs, [None] * len(costs))
    worst = sum(costs) / min(nd.speed for nd in nodes)
    deadline = worst * (1.0 + slack) + 1e-6
    p = plan_cluster(blocks, nodes, deadline, assignment=assignment,
                     error_margin=margin)
    q = plan_cluster_reference(blocks, nodes, deadline,
                               assignment=assignment, error_margin=margin)
    assert p.feasible == q.feasible
    assert p.pred_total_energy == pytest.approx(q.pred_total_energy, abs=1e-6)
    for a_np, b_np in zip(p.node_plans, q.node_plans):
        assert a_np.node.name == b_np.node.name
        assert len(a_np.blocks) == len(b_np.blocks)
        for a, b in zip(a_np.blocks, b_np.blocks):
            assert a.index == b.index
            assert a.rel_freq == b.rel_freq
            assert abs(a.pred_energy_j - b.pred_energy_j) <= 1e-9


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3000),
    fraction=st.floats(0.01, 0.3),
    n_boot=st.integers(10, 300),
    seed=st.integers(0, 10_000),
)
def test_sample_block_cost_matches_reference(n, fraction, n_boot, seed):
    """The (n_boot, k) gather bootstrap is bit-identical to the loop."""
    costs = np.random.default_rng(seed).lognormal(0.0, 0.7, n)
    a = sample_block_cost(costs, fraction=fraction, n_boot=n_boot, seed=seed)
    b = ref.sample_block_cost_reference(costs, fraction=fraction,
                                        n_boot=n_boot, seed=seed)
    assert a == b  # dataclass equality: every field identical


@settings(max_examples=10, deadline=None)
@given(
    n_blocks=st.integers(1, 30),
    seed=st.integers(0, 1000),
)
def test_sample_blocks_matches_reference(n_blocks, seed):
    rng = np.random.default_rng(seed)
    data = [rng.lognormal(0.0, 0.5, int(rng.integers(5, 500)))
            for _ in range(n_blocks)]
    assert sample_blocks(data, seed=seed) == \
        ref.sample_blocks_reference(data, seed=seed)


def test_sample_blocks_estimates_independent_of_set():
    """Block i's estimate must not depend on which other blocks are present
    (per-block seeding): dropping a block leaves the others unchanged."""
    rng = np.random.default_rng(0)
    data = [rng.lognormal(0.0, 0.5, 300) for _ in range(5)]
    full = sample_blocks(data, seed=9)
    assert sample_blocks(data[:3], seed=9) == full[:3]


def test_plan_dvo_matches_loop_semantics():
    """DVO: f_max everywhere, same totals as the scalar formulas."""
    from repro.core import TPU_V5E_POWER, block_time
    blocks = _blocks([1.0, 2.5, 0.3, 7.0], [None, (1e12, 2e10), None, None])
    plan = plan_dvo(blocks, 20.0)
    for b, bp in zip(blocks, plan.blocks):
        assert bp.rel_freq == 1.0
        assert bp.pred_time_s == pytest.approx(block_time(b, 1.0), abs=0)
        assert bp.pred_energy_j == pytest.approx(
            TPU_V5E_POWER.busy_energy(block_time(b, 1.0), 1.0, util=b.util),
            abs=0)


def test_schedule_plan_totals_cached():
    """pred_total_* are computed once (cached_property on the frozen plan)."""
    blocks = _blocks(np.linspace(1, 3, 64), [None] * 64)
    plan = plan_dvfs(blocks, 500.0, planner="global")
    first = plan.pred_total_energy
    assert "pred_total_energy" in plan.__dict__  # cached after first access
    assert plan.pred_total_energy is plan.__dict__["pred_total_energy"]
    assert first == sum(b.pred_energy_j for b in plan.blocks)
