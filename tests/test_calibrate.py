"""Calibration subsystem (``repro.calibrate``) + its engine/controller hooks.

Covers the PR's contract:

  * fit round-trip — on synthetic traces from known ground truth the
    fitters recover ``(p_idle, p_full, alpha)`` / ``(cost_per_record,
    mem_fraction)`` / node speeds within documented tolerance, across a
    noise grid; degenerate traces raise ``CalibrationError`` instead of
    returning confidently-wrong models;
  * engine emission — the runtime's actuator path emits one counter sample
    per executed block segment, and the samples' energies/work sum to the
    run report exactly;
  * closed loop — a plan calibrated from a measured trace dominates the
    default-constant plan on mis-modeled hardware (lower busy energy at
    equal deadline, or deadline met where constants miss), and online
    recalibration in the engine is two-run deterministic;
  * satellites — ``PowerModel`` construction validation,
    ``MigrationModel`` transfer latency (charged by the engine, weighed by
    ``plan_moves``), ``OnlineReplanner.on_telemetry`` first-observation /
    zero-length-window edges, serve ``replica_nodes``.
"""
import dataclasses

import numpy as np
import pytest

from repro.calibrate import (CalibrationError, CounterSample, CounterTrace,
                             OnlineCalibrator, TraceRecorder, calibrate_nodes,
                             fit_cost_model, fit_node_speeds, fit_power_model,
                             synthetic_trace)
from repro.cluster import (CalibratedNodeSpec, NodeSpec, OnlineReplanner,
                           plan_cluster)
from repro.core import BlockInfo, FrequencyLadder
from repro.core.energy import PowerModel
from repro.runtime import (ActuationModel, MigrationModel, RuntimeConfig,
                           plan_moves, run_cluster)

DEEP = FrequencyLadder(
    states=tuple(round(f, 2) for f in np.arange(0.35, 1.001, 0.05)))

# documented fit tolerances (relative) by trace noise level: exact traces
# recover to grid/refinement resolution, noisy ones degrade gracefully
POWER_TOL = {0.0: 0.01, 0.02: 0.06, 0.05: 0.15}
ALPHA_TOL = {0.0: 0.02, 0.02: 0.15, 0.05: 0.35}
SPEED_TOL = {0.0: 1e-9, 0.02: 0.02, 0.05: 0.05}


# --- PowerModel construction validation (satellite) --------------------------

class TestPowerModelValidation:
    def test_rejects_p_full_below_idle(self):
        with pytest.raises(ValueError, match="p_full"):
            PowerModel(p_full=50.0, p_idle=70.0)

    def test_rejects_p_full_equal_idle(self):
        with pytest.raises(ValueError, match="p_full"):
            PowerModel(p_full=70.0, p_idle=70.0)

    def test_rejects_nonpositive_powers(self):
        with pytest.raises(ValueError, match="positive"):
            PowerModel(p_full=200.0, p_idle=0.0)
        with pytest.raises(ValueError, match="positive"):
            PowerModel(p_full=-5.0, p_idle=-10.0)

    def test_rejects_nonpositive_alpha(self):
        with pytest.raises(ValueError, match="alpha"):
            PowerModel(alpha=0.0)
        with pytest.raises(ValueError, match="alpha"):
            PowerModel(alpha=-2.4)

    def test_accepts_valid_models(self):
        for kw in ({}, dict(p_full=95.0, p_idle=15.0, alpha=3.0),
                   dict(p_full=300.0, p_idle=40.0, alpha=1.6)):
            assert PowerModel(**kw).p_full > 0


# --- batch fitters -----------------------------------------------------------

class TestPowerFit:
    @pytest.mark.parametrize("noise", [0.0, 0.02, 0.05])
    @pytest.mark.parametrize("truth", [
        (230.0, 80.0, 2.0), (95.0, 15.0, 3.0), (300.0, 40.0, 1.2)])
    def test_round_trip(self, noise, truth):
        p_full, p_idle, alpha = truth
        power = PowerModel(p_full=p_full, p_idle=p_idle, alpha=alpha)
        tr = synthetic_trace("n0", power, n_samples=240, noise=noise, seed=7)
        pf = fit_power_model(tr)
        tol = POWER_TOL[noise]
        assert abs(pf.p_idle / p_idle - 1) < tol, pf
        assert abs(pf.p_full / p_full - 1) < tol, pf
        assert abs(pf.alpha - alpha) < ALPHA_TOL[noise], pf
        # the fitted model always satisfies PowerModel's own validation
        assert pf.to_power_model().p_full > pf.to_power_model().p_idle

    def test_too_few_samples_raises(self):
        tr = synthetic_trace("n0", PowerModel(), n_samples=2, seed=0)
        with pytest.raises(CalibrationError, match="3 samples"):
            fit_power_model(tr)

    def test_single_operating_point_raises(self):
        tr = synthetic_trace("n0", PowerModel(), n_samples=20,
                             freqs=(1.0,), util_range=(1.0, 1.0), seed=0)
        with pytest.raises(CalibrationError, match="under-determined"):
            fit_power_model(tr)

    def test_two_freqs_constant_util_raises(self):
        tr = synthetic_trace("n0", PowerModel(), n_samples=20,
                             freqs=(0.5, 1.0), util_range=(1.0, 1.0), seed=0)
        with pytest.raises(CalibrationError, match="under-determined"):
            fit_power_model(tr)

    def test_single_freq_varied_util_raises(self):
        # one frequency makes f^alpha a constant: utilization variation
        # identifies the LINE but alpha/slope stay confounded — without the
        # guard this fit returns a perfect-residual, wildly wrong p_full
        tr = synthetic_trace("n0", PowerModel(alpha=2.4), n_samples=40,
                             freqs=(0.5,), util_range=(0.4, 1.0), seed=0)
        with pytest.raises(CalibrationError, match="under-determined"):
            fit_power_model(tr)

    def test_two_freqs_varied_util_identifiable(self):
        power = PowerModel(p_full=210.0, p_idle=65.0, alpha=2.2)
        tr = synthetic_trace("n0", power, n_samples=200,
                             freqs=(0.6, 1.0), util_range=(0.5, 1.0), seed=1)
        pf = fit_power_model(tr)
        assert abs(pf.alpha - 2.2) < 0.02
        assert abs(pf.p_idle - 65.0) < 1.0


class TestCostFit:
    def _walls(self, cost, beta, n=120, seed=0, noise=0.0):
        rng = np.random.default_rng(seed)
        rec = rng.integers(50, 500, n).astype(float)
        f = rng.choice(np.arange(0.5, 1.001, 0.1), n)
        wall = rec * cost * np.maximum((1 - beta) / f, 1.0)
        if noise:
            wall = wall * (1 + noise * rng.standard_normal(n))
        return rec, f, wall

    @pytest.mark.parametrize("noise,tol", [(0.0, 1e-3), (0.03, 0.05)])
    @pytest.mark.parametrize("truth", [(0.004, 0.0), (0.01, 0.25),
                                       (0.002, 0.45)])
    def test_round_trip(self, noise, tol, truth):
        cost, beta = truth
        rec, f, wall = self._walls(cost, beta, noise=noise)
        cf = fit_cost_model(rec, f, wall)
        assert abs(cf.cost_per_record / cost - 1) < tol, cf
        assert abs(cf.mem_fraction - beta) < max(tol, 0.02), cf

    def test_unobserved_floor_is_conservative(self):
        # true zero-cost floor (0.2) below every observed frequency: the
        # data only bounds it — the fit must not claim more headroom than
        # the lowest observed frequency exhibited
        rec, f, wall = self._walls(0.005, 0.8)
        cf = fit_cost_model(rec, f, wall)
        assert 1.0 - cf.mem_fraction >= f.min() - 0.02
        assert abs(cf.cost_per_record / 0.005 - 1) < 1e-3

    def test_single_frequency_reports_pure_compute(self):
        rec = np.array([100.0, 200.0, 300.0])
        wall = rec * 0.01
        cf = fit_cost_model(rec, np.ones(3), wall)
        assert cf.mem_fraction == 0.0
        assert abs(cf.cost_per_record - 0.01) < 1e-9

    def test_roofline_helper_matches_fit(self):
        rec, f, wall = self._walls(0.004, 0.3)
        cf = fit_cost_model(rec, f, wall)
        rt = cf.roofline(100.0)
        assert abs(rt.time_at(1.0) - 100.0 * cf.cost_per_record) < 1e-9
        assert abs(rt.zero_cost_freq() - (1.0 - cf.mem_fraction)) < 1e-9

    def test_degenerate_raises(self):
        with pytest.raises(CalibrationError):
            fit_cost_model([0.0], [1.0], [0.0])


class TestSpeedFit:
    @pytest.mark.parametrize("noise", [0.0, 0.02, 0.05])
    def test_round_trip(self, noise):
        speeds = {"a": 0.75, "b": 1.0, "c": 1.4}
        tr = CounterTrace.concat([
            synthetic_trace(nm, PowerModel(), speed=s, n_samples=80,
                            noise=noise, seed=i)
            for i, (nm, s) in enumerate(speeds.items())])
        fits = fit_node_speeds(tr)
        for nm, s in speeds.items():
            assert abs(fits[nm].speed / s - 1) <= SPEED_TOL[noise], (nm, fits)

    def test_reference_normalization(self):
        tr = CounterTrace.concat([
            synthetic_trace("r0", PowerModel(), speed=2.0, seed=0),
            synthetic_trace("r1", PowerModel(), speed=3.0, seed=1)])
        fits = fit_node_speeds(tr, reference="r0")
        assert abs(fits["r0"].speed - 1.0) < 1e-9
        assert abs(fits["r1"].speed - 1.5) < 1e-9

    def test_empty_trace_raises(self):
        with pytest.raises(CalibrationError):
            fit_node_speeds(CounterTrace.concat([]))

    def test_zero_duration_samples_dropped(self):
        good = synthetic_trace("n0", PowerModel(), speed=1.2, n_samples=40,
                               seed=0)
        degenerate = CounterTrace.from_samples(
            [CounterSample(0.0, 0.0, "n0", 1.0, 1.0, 0.0, 0.0)] * 5)
        fits = fit_node_speeds(CounterTrace.concat([good, degenerate]))
        assert abs(fits["n0"].speed - 1.2) < 1e-9


# --- trace container ---------------------------------------------------------

class TestTraceFormat:
    def test_recorder_round_trip(self):
        rec = TraceRecorder()
        rec.record(0.0, 1.5, "n0", 0.8, 0.9, 120.0, 1.2)
        rec.record(1.5, 2.0, "n1", 1.0, 1.0, 300.0, 2.0)
        tr = rec.trace()
        assert len(tr) == 2 and tr.node_names() == ("n0", "n1")
        back = CounterTrace.from_samples(tr.to_samples())
        assert np.array_equal(back.energy_j, tr.energy_j)
        assert abs(tr.power_w[0] - 80.0) < 1e-9

    def test_zero_duration_power_is_zero(self):
        tr = CounterTrace.from_samples(
            [CounterSample(0.0, 0.0, "n0", 1.0, 1.0, 0.0, 0.0)])
        assert tr.power_w[0] == 0.0

    def test_validation(self):
        with pytest.raises(ValueError, match="length"):
            CounterTrace(np.zeros(2), np.zeros(1),
                         np.array(["a"], dtype=object), np.ones(1),
                         np.ones(1), np.ones(1), np.ones(1))
        with pytest.raises(ValueError, match="freq"):
            CounterTrace.from_samples(
                [CounterSample(0.0, 1.0, "n0", 0.0, 1.0, 1.0, 1.0)])


# --- engine trace emission ---------------------------------------------------

def _blocks(costs, utils=None):
    utils = utils if utils is not None else [1.0] * len(costs)
    return [BlockInfo(i, float(c), util=float(u))
            for i, (c, u) in enumerate(zip(costs, utils))]


def _mis_modeled(seed=0, n=48):
    """(blocks, believed nodes, true nodes, deadline) — hardware deviates
    >= 10% from the constructed constants in speed AND power."""
    rng = np.random.default_rng(seed)
    blocks = _blocks(rng.lognormal(1.0, 0.5, n), rng.uniform(0.6, 1.0, n))
    believed = [NodeSpec(f"n{k}", speed=1.0, ladder=DEEP) for k in range(3)]
    true = [NodeSpec("n0", speed=0.8, ladder=DEEP,
                     power=PowerModel(230.0, 80.0, 2.0)),
            NodeSpec("n1", speed=1.3, ladder=DEEP,
                     power=PowerModel(180.0, 60.0, 2.8)),
            NodeSpec("n2", speed=1.1, ladder=DEEP,
                     power=PowerModel(210.0, 65.0, 2.4))]
    deadline = sum(b.est_time_fmax for b in blocks) / 3 * 1.6
    return blocks, believed, true, deadline


class TestEngineTraceEmission:
    def test_samples_sum_to_report(self):
        blocks, believed, true, deadline = _mis_modeled()
        plan = plan_cluster(blocks, believed, deadline, assignment="lpt")
        rec = TraceRecorder()
        rep = run_cluster(plan, blocks,
                          config=RuntimeConfig(trace=rec, log_events=False),
                          true_nodes=true)
        tr = rec.trace()
        assert len(tr) == len(blocks)     # one segment per unsplit block
        assert abs(tr.energy_j.sum() - rep.total_energy_j) < 1e-6
        assert abs(tr.dur_s.sum()
                   - sum(nr.busy_s for nr in rep.node_reports)) < 1e-6
        # work_done is in planner units: the estimates the plan was built on
        assert abs(tr.work_done.sum()
                   - sum(b.est_time_fmax for b in blocks)) < 1e-6

    def test_midblock_switch_emits_per_segment(self):
        # actuation latency forces block 1 to launch at block 0's frequency
        # and switch mid-block -> two samples at their true frequencies
        from repro.cluster.planner import BlockPlan, ClusterPlan, NodePlan
        node = NodeSpec("n0", ladder=FrequencyLadder(states=(0.5, 1.0)))
        blocks = _blocks([4.0, 6.0])
        bps = tuple(
            BlockPlan(b.index, 50.0, f, node.block_time(b, f),
                      node.block_energy(b, node.block_time(b, f), f))
            for b, f in zip(blocks, (1.0, 0.5)))
        plan = ClusterPlan("cluster", 100.0, (NodePlan(node, bps),), True)
        rec = TraceRecorder()
        rep = run_cluster(
            plan, blocks,
            config=RuntimeConfig(actuation=ActuationModel(latency_s=1.0),
                                 trace=rec))
        tr = rec.trace()
        assert len(tr) == 3   # block 0 whole + block 1 split at the switch
        assert tuple(tr.for_node("n0").freq.tolist()[1:]) == (1.0, 0.5)
        assert abs(tr.energy_j.sum() - rep.total_energy_j) < 1e-9
        assert abs(tr.work_done.sum()
                   - sum(b.est_time_fmax for b in blocks)) < 1e-9


# --- the closed loop ---------------------------------------------------------

class TestClosedLoop:
    def test_calibrated_plan_dominates_defaults(self):
        """Measure on mis-modeled hardware -> fit -> replan: the calibrated
        plan must beat the default-constant plan (deadline met where the
        default misses, or strictly lower busy energy at equal deadline)."""
        blocks, believed, true, deadline = _mis_modeled()
        plan_def = plan_cluster(blocks, believed, deadline, assignment="lpt")
        rec = TraceRecorder()
        rep_def = run_cluster(plan_def, blocks,
                              config=RuntimeConfig(trace=rec,
                                                   log_events=False),
                              true_nodes=true)
        cal = calibrate_nodes(believed, rec.trace())
        for nd, t in zip(cal, true):
            assert isinstance(nd, CalibratedNodeSpec)
            assert abs(nd.speed / t.speed - 1) < 1e-6
            assert abs(nd.power.alpha - t.power.alpha) < 0.02
        plan_cal = plan_cluster(blocks, cal, deadline, assignment="lpt")
        rep_cal = run_cluster(plan_cal, blocks,
                              config=RuntimeConfig(log_events=False),
                              true_nodes=true)
        assert rep_cal.deadline_met
        assert (not rep_def.deadline_met) or \
            rep_cal.total_energy_j < rep_def.total_energy_j - 1e-6

    def test_plan_cluster_calibration_entry(self):
        blocks, believed, true, deadline = _mis_modeled()
        plan_def = plan_cluster(blocks, believed, deadline, assignment="lpt")
        rec = TraceRecorder()
        run_cluster(plan_def, blocks,
                    config=RuntimeConfig(trace=rec, log_events=False),
                    true_nodes=true)
        tr = rec.trace()
        via_kwarg = plan_cluster(blocks, believed, deadline,
                                 assignment="lpt", calibration=tr)
        explicit = plan_cluster(blocks, calibrate_nodes(believed, tr),
                                deadline, assignment="lpt")
        assert via_kwarg.pred_total_energy == explicit.pred_total_energy
        assert [np_.node.speed for np_ in via_kwarg.node_plans] == \
            [np_.node.speed for np_ in explicit.node_plans]

    def test_online_recalibration_two_run_deterministic(self):
        blocks, believed, true, deadline = _mis_modeled()
        plan = plan_cluster(blocks, believed, deadline, assignment="lpt")

        def run():
            cfg = RuntimeConfig(online=True,
                                calibrator=OnlineCalibrator(),
                                ewma_alpha=0.5, replan_threshold=0.1)
            return run_cluster(plan, blocks, config=cfg, est_blocks=blocks,
                               true_nodes=true)

        r1, r2 = run(), run()
        assert r1.event_log == r2.event_log
        assert r1 == r2

    def test_online_recalibration_recovers_speed(self):
        """The calibrator's fitted spec reaches the controller: after the
        run, the straggler node's spec carries the fitted speed."""
        blocks, believed, true, deadline = _mis_modeled()
        plan = plan_cluster(blocks, believed, deadline, assignment="lpt")
        cal = OnlineCalibrator(min_samples=4, refit_every=2)
        cfg = RuntimeConfig(online=True, calibrator=cal, ewma_alpha=0.5,
                            replan_threshold=0.1)
        rt_kwargs = dict(config=cfg, est_blocks=blocks, true_nodes=true)
        from repro.runtime import ClusterRuntime
        rt = ClusterRuntime(plan, blocks, **rt_kwargs)
        rt.run()
        assert rt.controller.recalibrations  # the hook actually fired
        for nd_true in true:
            sf = cal.speed_fit(nd_true.name)
            if sf is not None:
                assert abs(sf.speed / nd_true.speed - 1) < 0.05


# --- OnlineReplanner.on_telemetry edges (satellite) --------------------------

def _controller(costs=(4.0, 6.0, 2.0), deadline=40.0, **kw):
    blocks = _blocks(costs)
    nodes = [NodeSpec("n0", ladder=DEEP), NodeSpec("n1", ladder=DEEP)]
    plan = plan_cluster(blocks, nodes, deadline, assignment="lpt")
    return OnlineReplanner(plan, blocks, **kw), plan, blocks


class TestOnTelemetryEdges:
    def test_first_observation_no_replan(self):
        ctrl, plan, _ = _controller()
        name = plan.node_plans[0].node.name
        bp = ctrl.next_block(name)
        # first observation: detector is in warmup, drift estimate moves to
        # the observed ratio, and the call must neither crash nor replan
        assert ctrl.on_telemetry(name, bp.pred_time_s * 3.0) in (False, True)
        assert ctrl.drift_of(name) > 0

    def test_zero_length_observation(self):
        ctrl, plan, _ = _controller()
        name = plan.node_plans[0].node.name
        ctrl.on_telemetry(name, 0.0)   # zero-length window: ratio 0
        assert ctrl.drift_of(name) >= 1e-6   # clamped, never 0 or NaN
        assert np.isfinite(ctrl.predicted_finish(name))

    def test_zero_length_samples_never_poison_calibrator(self):
        cal = OnlineCalibrator(min_samples=2, refit_every=1)
        ctrl, plan, _ = _controller(costs=(4.0,) * 8)
        ctrl.calibrator = cal
        name = plan.node_plans[0].node.name
        zero = CounterSample(0.0, 0.0, name, 1.0, 1.0, 0.0, 0.0)
        for _ in range(4):   # refits run, fitters drop the empty windows
            ctrl.on_telemetry(name, 0.0, samples=(zero,))
        assert cal.speed_fit(name) is None
        assert cal.power_fit(name) is None

    def test_empty_samples_tuple_is_noop(self):
        cal = OnlineCalibrator()
        ctrl, plan, _ = _controller()
        ctrl.calibrator = cal
        name = plan.node_plans[0].node.name
        ctrl.on_telemetry(name, 1.0, samples=())
        assert cal.n_refits == 0


# --- MigrationModel (satellite) ----------------------------------------------

def _migration_scenario():
    """A straggler that must move work: loaded node, light neighbour.
    Blocks are small so the fault is OBSERVED early enough that targets
    still have deadline room to accept moves."""
    blocks = _blocks([2.0] * 6 + [1.0, 1.0])
    nodes = [NodeSpec("n0", ladder=DEEP), NodeSpec("n1", ladder=DEEP)]
    deadline = 20.0
    plan = plan_cluster(blocks, nodes, deadline,
                        assignment=[0] * 6 + [1, 1])
    return blocks, nodes, deadline, plan


class TestMigrationModel:
    def test_validation(self):
        with pytest.raises(ValueError):
            MigrationModel(latency_s_per_block=-1.0)

    def test_engine_charges_transfer_latency(self):
        blocks, nodes, deadline, plan = _migration_scenario()
        from repro.cluster import SlowdownEvent
        events = [SlowdownEvent("n0", after_block=0, factor=2.0)]
        lat = 0.75
        cfg = RuntimeConfig(online=True, migrate=True,
                            migration=MigrationModel(lat),
                            ewma_alpha=0.9, replan_threshold=0.05)
        rep = run_cluster(plan, blocks, config=cfg, events=events,
                          est_blocks=blocks)
        assert rep.n_migrations >= 1
        starts = {}   # block index -> first actual launch on the dst
        for ev in rep.event_log:
            if ev[1] == "block_start" and len(ev) > 4 and ev[3] != "deferred":
                starts.setdefault((ev[2], ev[3]), ev[0])
        for mv in rep.migrations:
            assert mv.ready_s == pytest.approx(mv.time + lat)
            started = starts.get((mv.dst, mv.block_index))
            if started is not None:
                assert started >= mv.ready_s - 1e-9

    def test_zero_latency_matches_free_moves(self):
        blocks, nodes, deadline, plan = _migration_scenario()
        from repro.cluster import SlowdownEvent
        events = [SlowdownEvent("n0", after_block=0, factor=2.0)]
        base = dict(online=True, migrate=True, ewma_alpha=0.9,
                    replan_threshold=0.05)
        free = run_cluster(plan, blocks, est_blocks=blocks, events=events,
                           config=RuntimeConfig(**base))
        zero = run_cluster(plan, blocks, est_blocks=blocks, events=events,
                           config=RuntimeConfig(
                               migration=MigrationModel(0.0), **base))
        assert free == zero

    def test_plan_moves_weighs_latency(self):
        """A destination that fits the block only if it arrived instantly
        must be refused once the transfer latency is charged."""
        blocks, nodes, deadline, plan = _migration_scenario()
        big_lat = deadline  # nothing can both transfer and finish in time

        def controller_with_slowdown():
            ctrl = OnlineReplanner(plan, blocks, ewma_alpha=0.9,
                                   replan_threshold=1e9)
            name = plan.node_plans[0].node.name
            for _ in range(2):   # drive the drift estimate up
                bp = ctrl.next_block(name)
                ctrl.observe(name, bp.pred_time_s * 4.0)
            return ctrl, name

        ctrl, name = controller_with_slowdown()
        free_moves = plan_moves(ctrl, name, 1.0)
        ctrl2, name2 = controller_with_slowdown()
        costly = plan_moves(ctrl2, name2, 1.0,
                            migration=MigrationModel(big_lat))
        assert len(free_moves) >= 1
        assert len(costly) == 0
        # and dst predictions account for the wire: with a mild latency the
        # recorded dst_pred reflects arrival >= now + latency
        ctrl3, name3 = controller_with_slowdown()
        mild = plan_moves(ctrl3, name3, 1.0,
                          migration=MigrationModel(2.0))
        for mv in mild:
            assert mv.dst_pred_s >= 1.0 + 2.0 - 1e-9


# --- serve: per-replica calibrated specs -------------------------------------

class TestServeReplicaNodes:
    def _engine(self, sc):
        # ServingEngine.__init__ needs model params; _replica_speeds /
        # _plan_replicas only read sc + actuator, so construct bare
        from repro.serve.engine import ServingEngine
        from repro.train.dvfs_controller import SimulatedActuator
        eng = ServingEngine.__new__(ServingEngine)
        eng.sc = sc
        eng.actuator = SimulatedActuator(None)
        return eng

    def test_replica_nodes_speeds_normalized_to_replica0(self):
        from repro.serve import ServeConfig
        nodes = (NodeSpec("r0", speed=2.0), NodeSpec("r1", speed=1.0),
                 NodeSpec("r2", speed=3.0))
        eng = self._engine(ServeConfig(replicas=3, replica_nodes=nodes))
        assert eng._replica_speeds() == (1.0, 0.5, 1.5)

    def test_replica_nodes_length_mismatch(self):
        from repro.serve import ServeConfig
        eng = self._engine(ServeConfig(replicas=2,
                                       replica_nodes=(NodeSpec("r0"),)))
        with pytest.raises(ValueError, match="replica_nodes"):
            eng._replica_speeds()

    def test_calibrated_specs_flow_into_window_plan(self):
        from repro.serve import ServeConfig
        tr = CounterTrace.concat([
            synthetic_trace("r0", PowerModel(210.0, 60.0, 2.1), speed=1.0,
                            seed=0),
            synthetic_trace("r1", PowerModel(230.0, 80.0, 2.9), speed=0.8,
                            seed=1)])
        cal = calibrate_nodes([NodeSpec("r0"), NodeSpec("r1")], tr)
        eng = self._engine(ServeConfig(replicas=2,
                                       replica_nodes=tuple(cal)))
        plan0 = eng._plan_replicas(n_windows=4, window_fmax_s=0.5,
                                   deadline=5.0)
        assert len(plan0.blocks) == 4
        # each replica's plan node keeps ITS calibrated power model
        powers = [np_.node.power.alpha
                  for np_ in eng.cluster_plan.node_plans]
        assert abs(powers[0] - 2.1) < 0.05
        assert abs(powers[1] - 2.9) < 0.05
        # replica 1's windows priced at its own (slower) speed
        assert eng.cluster_plan.node_plans[1].node.speed < 1.0
