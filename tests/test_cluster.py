"""Cluster subsystem: deterministic assignment, online re-planning
convergence, and mid-run fault recovery."""
import dataclasses

import numpy as np
import pytest

from repro.core import BlockInfo, FrequencyLadder, zipf_block_sizes
from repro.cluster import (NodeSpec, SlowdownEvent, assign_blocks,
                           plan_cluster, plan_independent, simulate_cluster)

DEEP_LADDER = FrequencyLadder(
    states=tuple(round(f, 2) for f in np.arange(0.35, 1.001, 0.05)))


def _zipf_blocks(n=24, z=1.0, seed=0, mean_cost=5.0):
    sizes = zipf_block_sizes(n, 10000, z=z, seed=seed)
    costs = sizes / sizes.mean() * mean_cost
    return [BlockInfo(i, float(c)) for i, c in enumerate(costs)]


def _nodes(speeds=(1.0, 0.7, 1.3), ladder=None):
    kw = {"ladder": ladder} if ladder is not None else {}
    return [NodeSpec(f"n{k}", speed=s, **kw) for k, s in enumerate(speeds)]


def _rr_fmax_makespan(blocks, nodes):
    groups = assign_blocks(blocks, nodes, strategy="round_robin")
    return max(sum(b.est_time_fmax for b in g) / n.speed
               for g, n in zip(groups, nodes))


def test_assignment_deterministic_under_fixed_seed():
    """Same seed -> identical blocks -> identical assignment and freqs."""
    runs = []
    for _ in range(2):
        blocks = _zipf_blocks(seed=7)
        nodes = _nodes()
        plan = plan_cluster(blocks, nodes, _rr_fmax_makespan(blocks, nodes) * 1.3)
        runs.append((plan.assignment(),
                     [tuple((bp.index, bp.rel_freq) for bp in np_.blocks)
                      for np_ in plan.node_plans]))
    assert runs[0] == runs[1]


def test_lpt_places_giant_block_on_fast_node():
    """Uniform-machine LPT: the dominant block must land where it finishes
    earliest — the fastest node — even though round-robin would not put it
    there."""
    blocks = [BlockInfo(0, 50.0)] + [BlockInfo(i, 1.0) for i in range(1, 10)]
    nodes = _nodes(speeds=(1.0, 0.7, 1.6))
    groups = assign_blocks(blocks, nodes, strategy="lpt")
    assert any(b.index == 0 for b in groups[2])


def test_cluster_beats_independent_on_heterogeneous_nodes():
    """Acceptance: >=3 heterogeneous nodes, equal deadline, LPT + cross-node
    greedy saves energy versus per-node independent Algorithm 1."""
    for z in (1.0, 2.0):
        blocks = _zipf_blocks(z=z)
        nodes = _nodes()
        deadline = _rr_fmax_makespan(blocks, nodes) * 1.2
        r_ind = simulate_cluster(plan_independent(blocks, nodes, deadline),
                                 blocks)
        r_clu = simulate_cluster(plan_cluster(blocks, nodes, deadline), blocks)
        assert r_clu.deadline_met
        assert r_clu.total_energy_j < r_ind.total_energy_j


def test_replanning_converges_without_oscillation():
    """Constant estimate drift: at most one correction per node, and once a
    node clocked up it never swings back down (no frequency flip-flop)."""
    est = [BlockInfo(i, 5.0) for i in range(18)]
    truth = [dataclasses.replace(b, est_time_fmax=b.est_time_fmax * 1.5)
             for b in est]
    nodes = _nodes(speeds=(1.0, 0.8, 1.25))
    deadline = 5.0 * 18 / sum(n.speed for n in nodes) * 2.0
    # pin the balanced spread: this test exercises the feedback loop, not
    # the assignment search (pack would idle a node and shift the drift mix)
    plan = plan_cluster(est, nodes, deadline, assignment="lpt")
    rep = simulate_cluster(plan, truth, est_blocks=est, online=True,
                           ewma_alpha=0.5, replan_threshold=0.1)
    assert rep.deadline_met
    # converged: bounded corrections, not one per block
    assert 1 <= rep.n_replans <= 2 * len(nodes)
    for nr in rep.node_reports:
        high_water = nr.freqs[0]
        for f in nr.freqs:
            # never drops below an already-reached level by more than one
            # ladder step (greedy may spread remaining slack one step wide)
            assert f >= high_water - 0.05 - 1e-9
            high_water = max(high_water, f)


def test_no_replan_when_estimates_hold():
    """Truth == estimate: the controller must stay quiet."""
    blocks = _zipf_blocks()
    nodes = _nodes()
    plan = plan_cluster(blocks, nodes, _rr_fmax_makespan(blocks, nodes) * 1.3)
    rep = simulate_cluster(plan, blocks, online=True)
    assert rep.n_replans == 0


def test_midrun_slowdown_recovered_by_online_replanning():
    """A 2x slowdown on one node mid-run: the static plan blows the deadline,
    the online re-planner clocks the late node up and still meets it."""
    blocks = [BlockInfo(i, 5.0) for i in range(24)]
    nodes = _nodes(speeds=(1.0, 0.8, 1.25), ladder=DEEP_LADDER)
    deadline = max(sum(b.est_time_fmax for b in g) / n.speed
                   for g, n in zip(assign_blocks(blocks, nodes), nodes)) * 2.2
    plan = plan_cluster(blocks, nodes, deadline, assignment="lpt")
    n0_blocks = len(plan.node_plans[0].blocks)
    events = [SlowdownEvent("n0", after_block=n0_blocks // 2 - 1, factor=2.0)]

    r_static = simulate_cluster(plan, blocks, events=events)
    r_online = simulate_cluster(plan, blocks, events=events, online=True,
                                ewma_alpha=0.7, replan_threshold=0.1)
    assert not r_static.deadline_met
    assert r_online.deadline_met
    assert r_online.n_replans >= 1
    # the slowed node visibly clocked up
    n0 = next(nr for nr in r_online.node_reports if nr.name == "n0")
    assert max(n0.freqs) > min(n0.freqs)


def test_explicit_assignment_pins_blocks():
    blocks = [BlockInfo(i, float(i + 1)) for i in range(6)]
    nodes = _nodes(speeds=(1.0, 1.0))
    plan = plan_cluster(blocks, nodes, 100.0,
                        assignment=[0, 0, 0, 1, 1, 1])
    asn = plan.assignment()
    assert all(asn[i] == "n0" for i in range(3))
    assert all(asn[i] == "n1" for i in range(3, 6))
    with pytest.raises(ValueError):
        plan_cluster(blocks, nodes, 100.0, assignment=[0, 1])


def test_node_spec_validation():
    with pytest.raises(ValueError):
        NodeSpec("bad", speed=0.0)
    with pytest.raises(ValueError):
        assign_blocks([BlockInfo(0, 1.0)], _nodes(), strategy="nope")


def test_replan_hysteresis_exact_threshold_edge():
    """Drift landing EXACTLY on the threshold must not trigger a re-plan —
    the hysteresis gate is strict — while one step beyond must."""
    from repro.cluster import OnlineReplanner
    est = [BlockInfo(i, 5.0) for i in range(8)]
    nodes = _nodes(speeds=(1.0,))
    plan = plan_cluster(est, nodes, 5.0 * 8 * 2.0, assignment="lpt")
    ctl = OnlineReplanner(plan, est, replan_threshold=0.5, ewma_alpha=0.5)
    bp = ctl.next_block("n0")
    base = nodes[0].block_time(est[bp.index], bp.rel_freq)
    # first observation seeds the EWMA: drift == 1.5, rel change == 0.5
    assert ctl.observe("n0", base * 1.5) is False
    assert ctl.total_replans == 0
    # constant drift: the EWMA holds, still exactly at the threshold
    bp = ctl.next_block("n0")
    base = nodes[0].block_time(est[bp.index], bp.rel_freq)
    assert ctl.observe("n0", base * 1.5) is False
    assert ctl.total_replans == 0
    # one step past the edge: the gate opens
    bp = ctl.next_block("n0")
    base = nodes[0].block_time(est[bp.index], bp.rel_freq)
    assert ctl.observe("n0", base * 2.6) is True
    assert ctl.total_replans == 1


def test_replan_recovery_does_not_oscillate():
    """Slowdown then full recovery (2x, then x0.5 back to true speed): the
    controller corrects up once and relaxes back down at most once — the
    frequency trace has no flip-flop."""
    blocks = [BlockInfo(i, 5.0) for i in range(16)]
    nodes = _nodes(speeds=(1.0,), ladder=DEEP_LADDER)
    deadline = 5.0 * 16 * 1.9
    plan = plan_cluster(blocks, nodes, deadline, assignment="lpt")
    events = [SlowdownEvent("n0", after_block=3, factor=2.0),
              SlowdownEvent("n0", after_block=9, factor=0.5)]
    rep = simulate_cluster(plan, blocks, online=True, events=events,
                           ewma_alpha=0.7, replan_threshold=0.1)
    assert rep.deadline_met
    nr = rep.node_reports[0]
    # direction changes in the frequency trace: up once (slowdown), down
    # once (recovery) — any third change is an oscillation
    dirs = [np.sign(b - a) for a, b in zip(nr.freqs, nr.freqs[1:])
            if abs(b - a) > 1e-9]
    changes = sum(1 for a, b in zip(dirs, dirs[1:]) if a != b)
    assert changes <= 2, nr.freqs
    # bounded corrections, not one per block
    assert 1 <= rep.n_replans <= 4


def test_slowdown_event_ties_are_input_order_invariant():
    """Two+ SlowdownEvents with the same trigger used to apply in input
    order, silently deciding the FP product; they now apply in the total
    order (after_block, factor), so any input permutation simulates
    identically — on the engine and on the reference loop."""
    from repro.cluster import simulate_cluster_reference
    blocks = _zipf_blocks(n=12, seed=3)
    nodes = _nodes(speeds=(1.0, 0.8))
    plan = plan_cluster(blocks, nodes,
                        _rr_fmax_makespan(blocks, nodes) * 1.6)
    evs = [SlowdownEvent("n0", 2, 1.1), SlowdownEvent("n0", 2, 1.3),
           SlowdownEvent("n0", 2, 1.7), SlowdownEvent("n1", 1, 1.2)]
    perms = [evs, evs[::-1], [evs[2], evs[0], evs[3], evs[1]]]
    reports = [simulate_cluster(plan, blocks, events=p) for p in perms]
    refs = [simulate_cluster_reference(plan, blocks, events=p)
            for p in perms]
    assert reports[0] == reports[1] == reports[2]
    assert refs[0] == refs[1] == refs[2]
    assert reports[0] == refs[0]  # and the engine matches the loop oracle
