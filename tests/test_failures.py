"""Failure model, recovery ladder, triage, and the chaos campaign.

The contract of ``repro.runtime.failures`` / ``repro.runtime.recovery``:

  (a) conservation — across seeded chaos campaigns every planned block
      either finishes exactly once or is explicitly reported missed, the
      event-log energy reconstructs the report's ledger (crash-burned
      energy included), and nothing ever raises;
  (b) bit-identity — the vector engine matches the scalar oracle (report
      AND event log) under crashes, and a zero-failure run is bitwise
      UNCHANGED by merely configuring recovery;
  (c) crash-edge interleavings — a crash at the exact timestamp of a
      pending frequency switch, a crash with a migration transfer window
      open (source and target side), the last feasible node crashing, and
      a repair landing after the deadline all degrade gracefully;
  (d) salvage arithmetic — ``salvage_fraction`` is exact on hand-priced
      segment logs;
  (e) triage — ``classify_ratios`` separates uniform shift (interference)
      from positive trend (degrading) from high dispersion (data skew).
"""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.calibrate.triage import classify_ratios
from repro.cluster.node import NodeSpec
from repro.cluster.planner import plan_cluster
from repro.core.energy import FrequencyLadder, PowerModel
from repro.core.scheduler import BlockInfo
from repro.runtime import (ActuationModel, CheckpointModel, MigrationModel,
                           NodeFailureEvent, RecoveryPolicy, RuntimeConfig,
                           check_conservation, run_campaign, run_cluster)
from repro.runtime.failures import chaos_scenario
from repro.runtime.recovery import salvage_fraction


# --- fixtures ---------------------------------------------------------------

def _cluster(n_blocks=18, k=3, slack=1.8, seed=7, drift=1.05):
    """Round-robin spread (every node holds work — crashes always have
    something to kill) with the deadline ``slack`` times the slowest
    node's TRUE round-robin time."""
    rng = np.random.default_rng(seed)
    blocks = [BlockInfo(index=i,
                        est_time_fmax=float(rng.uniform(0.5, 2.0)),
                        util=float(rng.uniform(0.5, 1.0)),
                        records=float(rng.integers(100, 1000)))
              for i in range(n_blocks)]
    ladder = FrequencyLadder((0.5, 0.7, 0.85, 1.0))
    nodes = [NodeSpec(f"n{j}", ladder=ladder,
                      power=PowerModel(p_idle=30.0, p_full=110.0, alpha=2.0),
                      speed=1.0 + 0.1 * j)
             for j in range(k)]
    truth = [dataclasses.replace(b, est_time_fmax=b.est_time_fmax * drift)
             for b in blocks]
    per_node = [sum(t.est_time_fmax for t in truth[j::k]) / nodes[j].speed
                for j in range(k)]
    deadline = max(per_node) * slack
    plan = plan_cluster(blocks, nodes, deadline_s=deadline,
                        assignment="round_robin")
    return blocks, truth, nodes, plan


def _run_both(plan, truth, cfg_kwargs, events, blocks):
    """(scalar, vector) reports from FRESH configs; asserts bit-identity."""
    a = run_cluster(plan, truth, config=RuntimeConfig(**cfg_kwargs),
                    events=events, est_blocks=blocks, engine="scalar")
    v = run_cluster(plan, truth, config=RuntimeConfig(**cfg_kwargs),
                    events=events, est_blocks=blocks, engine="vector")
    assert a == v
    assert a.event_log == v.event_log
    return a, v


# --- (a) the chaos campaign -------------------------------------------------

def test_chaos_campaign_conserves():
    """Seeded campaign: conservation, determinism, scalar==vector.  The
    tier-1 slice runs 30 scenarios; ``benchmarks/run.py --section
    failures`` runs the full 200 the acceptance bar names."""
    out = run_campaign(30, base_seed=1000)
    assert out["violations"] == []
    assert out["n_crashes"] > 0          # the campaign actually crashed nodes
    assert out["recovery_decisions"] > 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_scalar_vector_identity_under_crashes(seed):
    sc = chaos_scenario(seed)
    a = run_cluster(sc.plan, sc.truth, config=sc.config(), events=sc.events,
                    est_blocks=sc.blocks, engine="scalar")
    v = run_cluster(sc.plan, sc.truth, config=sc.config(), events=sc.events,
                    est_blocks=sc.blocks, engine="vector")
    assert a == v
    assert a.event_log == v.event_log
    assert check_conservation(a, sc.plan) == []


# --- (b) zero-failure bit-identity ------------------------------------------

def test_recovery_config_is_inert_without_failures():
    """Configuring recovery (checkpoint, triage, the lot) must not move a
    single bit of a run that never crashes."""
    blocks, truth, nodes, plan = _cluster()
    base = dict(online=True, migrate=True, log_events=True,
                migration=MigrationModel(latency_s_per_block=0.5,
                                         energy_j_per_record=0.005))
    with_rp = dict(base, recovery=RecoveryPolicy(
        checkpoint=CheckpointModel(interval_s=0.5), use_triage=True))
    a, _ = _run_both(plan, truth, base, [], blocks)
    b, _ = _run_both(plan, truth, with_rp, [], blocks)
    assert a == b
    assert a.event_log == b.event_log
    assert a.n_crashes == 0 and a.missed_blocks == ()


# --- (c) crash-edge interleavings -------------------------------------------

def test_crash_at_exact_freq_switch_timestamp():
    """A crash landing at the very timestamp of a pending FREQ_SWITCH:
    the switch settles first (kind priority), the crash then kills the
    block — no double accounting, oracle and vector agree."""
    blocks, truth, nodes, plan = _cluster(seed=11)
    cfg = dict(online=True, log_events=True,
               actuation=ActuationModel(latency_s=0.25),
               recovery=RecoveryPolicy())
    clean, _ = _run_both(plan, truth, cfg, [], blocks)
    switches = [e for e in clean.event_log if e[1] == "freq_switch"]
    if not switches:
        pytest.skip("scenario produced no mid-run switch to collide with")
    t, node = float(switches[0][0]), switches[0][2]
    ev = [NodeFailureEvent(time=t, node=node, flavor="transient",
                           repair_s=1.0)]
    rep, _ = _run_both(plan, truth, cfg, ev, blocks)
    assert rep.n_crashes == 1 and rep.n_repairs == 1
    assert check_conservation(rep, plan) == []


def test_crash_during_transfer_aborts_wire():
    """Crash of the migration SOURCE while its transfer window is open:
    the wire watts are released at the crash instant and the scheduled
    WIRE_RELEASE is voided (no double release)."""
    blocks, truth, nodes, plan = _cluster(n_blocks=24, slack=1.3, seed=3)
    cfg = dict(online=True, migrate=True, log_events=True,
               migration=MigrationModel(latency_s_per_block=1.5,
                                        energy_j_per_record=0.01),
               recovery=RecoveryPolicy(), error_margin=0.15)
    from repro.runtime import FaultEvent
    base_ev = [FaultEvent(time=0.5, node="n0", factor=3.0)]
    clean, _ = _run_both(plan, truth, cfg, base_ev, blocks)
    open_mv = [mv for mv in clean.migrations if mv.ready_s > mv.time + 1e-9]
    if not open_mv:
        pytest.skip("scenario produced no transfer window to collide with")
    mv = open_mv[0]
    t_mid = (mv.time + mv.ready_s) / 2.0
    for victim in (mv.src, mv.dst):        # crash each side of the wire
        ev = base_ev + [NodeFailureEvent(time=t_mid, node=victim,
                                         flavor="permanent")]
        rep, _ = _run_both(plan, truth, cfg, ev, blocks)
        assert check_conservation(rep, plan) == []
        if victim == mv.src:
            downs = [e for e in rep.event_log
                     if e[1] == "node_down" and len(e) >= 9
                     and e[2] == victim]
            assert downs and downs[0][8] > 0.0   # wire watts aborted
            stale = [e for e in rep.event_log
                     if e[1] == "wire_release" and e[-1] == "stale"]
            assert stale                          # release voided, not reapplied


def test_last_feasible_node_crashing_degrades_gracefully():
    """Every node permanently down mid-run: the run ENDS with a report —
    missed blocks enumerated, no exception, both engines agree."""
    blocks, truth, nodes, plan = _cluster(k=2, seed=5)
    deadline = plan.deadline_s
    cfg = dict(online=True, log_events=True,
               recovery=RecoveryPolicy(checkpoint=CheckpointModel(0.4)))
    ev = [NodeFailureEvent(time=0.3 * deadline, node="n0",
                           flavor="permanent"),
          NodeFailureEvent(time=0.5 * deadline, node="n1",
                           flavor="permanent")]
    rep, _ = _run_both(plan, truth, cfg, ev, blocks)
    assert rep.missed_blocks                     # which blocks, not a raise
    assert rep.lost_records > 0
    assert not rep.deadline_met
    assert check_conservation(rep, plan) == []
    # the second crash found no survivors: graceful degradation on record
    assert any(d.action == "stranded" for d in rep.recoveries)


def test_repair_after_deadline_runs_late_not_lost():
    """A lone node's transient outage whose repair lands past the deadline:
    the frozen queue still runs to completion (late), nothing is lost."""
    blocks, truth, nodes, plan = _cluster(k=1, slack=1.4, seed=9)
    deadline = plan.deadline_s
    ev = [NodeFailureEvent(time=0.5 * deadline, node="n0",
                           flavor="transient", repair_s=deadline)]
    cfg = dict(online=True, log_events=True, recovery=RecoveryPolicy())
    rep, _ = _run_both(plan, truth, cfg, ev, blocks)
    assert rep.missed_blocks == () and rep.lost_records == 0
    assert rep.makespan_s > deadline and not rep.deadline_met
    assert check_conservation(rep, plan) == []


def test_wait_versus_migrate_ladder():
    """Short MTTR + slack => rung 1 (wait); permanent crash => rung 2
    (migrate), and the recovery meets the deadline the wait cannot."""
    blocks, truth, nodes, plan = _cluster(n_blocks=18, k=3, slack=2.2,
                                          seed=21)
    deadline = plan.deadline_s
    cfg = dict(online=True, migrate=True, log_events=True,
               recovery=RecoveryPolicy())
    short = [NodeFailureEvent(time=0.3 * deadline, node="n0",
                              flavor="transient",
                              repair_s=0.05 * deadline)]
    rep_s, _ = _run_both(plan, truth, cfg, short, blocks)
    assert any(d.action == "wait" for d in rep_s.recoveries)
    perm = [NodeFailureEvent(time=0.3 * deadline, node="n0",
                             flavor="permanent")]
    rep_p, _ = _run_both(plan, truth, cfg, perm, blocks)
    assert any(d.action == "migrate" for d in rep_p.recoveries)
    assert rep_p.missed_blocks == ()     # survivors absorbed the orphans
    for rep in (rep_s, rep_p):
        assert check_conservation(rep, plan) == []


def test_checkpoint_salvage_shrinks_reruns():
    """With checkpointing, a killed block's re-run prices only its
    remainder: total busy seconds drop vs the no-checkpoint run of the
    same crash, and the salvaged fraction lands in the report."""
    blocks, truth, nodes, plan = _cluster(n_blocks=12, k=2, slack=2.0,
                                          seed=13)
    deadline = plan.deadline_s
    ev = [NodeFailureEvent(time=0.2 * deadline, node="n0",
                           flavor="transient", repair_s=0.05 * deadline)]
    base = dict(online=True, log_events=True)
    rep_no, _ = _run_both(plan, truth,
                          dict(base, recovery=RecoveryPolicy()), ev, blocks)
    rep_ck, _ = _run_both(
        plan, truth,
        dict(base, recovery=RecoveryPolicy(
            checkpoint=CheckpointModel(interval_s=0.02 * deadline))),
        ev, blocks)
    if rep_ck.failed_busy_s == 0:
        pytest.skip("crash landed between blocks — nothing in flight")
    salvaged = sum(nr.salvaged_frac for nr in rep_ck.node_reports)
    if salvaged == 0:
        pytest.skip("crash landed before the first checkpoint tick")
    total_busy_no = sum(nr.busy_s for nr in rep_no.node_reports)
    total_busy_ck = sum(nr.busy_s for nr in rep_ck.node_reports)
    assert total_busy_ck < total_busy_no
    for rep in (rep_no, rep_ck):
        assert check_conservation(rep, plan) == []


# --- (d) salvage arithmetic -------------------------------------------------

class _FakeInflight:
    def __init__(self, seg_log):
        self.seg_log = seg_log


def test_salvage_fraction_exact():
    # one 10 s segment worth 0.8 of the block; interval 3 ticks at 3,6,9
    # -> last tick 9 -> linear within the segment: 0.8 * 9/10
    fl = _FakeInflight([(0.0, 10.0, 1.0, 0.8, 5.0)])
    assert salvage_fraction(fl, 3.0) == pytest.approx(0.8 * 0.9)
    # interval longer than the runtime: no tick landed, nothing salvaged
    assert salvage_fraction(fl, 11.0) == 0.0
    # two segments 4 s + 6 s at different freqs, work 0.3 / 0.4; crash at 10
    fl2 = _FakeInflight([(0.0, 4.0, 1.0, 0.3, 2.0),
                         (4.0, 6.0, 0.5, 0.4, 2.0)])
    # interval 4 -> ticks 4, 8; last tick 8 sits 4 s into segment 2
    assert salvage_fraction(fl2, 4.0) == pytest.approx(0.3 + 0.4 * (4 / 6))
    # interval 5 -> last tick 10 == the crash instant: everything executed
    # by then counts (both segments whole)
    assert salvage_fraction(fl2, 5.0) == pytest.approx(0.7)
    # interval 3 -> last tick 9, 5 s into segment 2
    assert salvage_fraction(fl2, 3.0) == pytest.approx(0.3 + 0.4 * (5 / 6))
    # empty log
    assert salvage_fraction(_FakeInflight([]), 1.0) == 0.0


# --- (e) triage -------------------------------------------------------------

def test_triage_classifies_canonical_shapes():
    rng = np.random.default_rng(0)
    flat = [1.0 + 1e-3 * float(rng.standard_normal()) for _ in range(24)]
    assert classify_ratios(flat).cause == "none"
    shifted = [1.5 + 1e-3 * float(rng.standard_normal()) for _ in range(24)]
    d = classify_ratios(shifted)
    assert d.cause == "interference" and d.severity > 0.3
    climbing = [1.0 + 0.06 * i for i in range(24)]
    d = classify_ratios(climbing)
    assert d.cause == "degrading" and d.trend > 0
    skewed = [float(np.exp(rng.standard_normal() * 0.6)) for _ in range(48)]
    assert classify_ratios(skewed).cause == "data_skew"
    assert classify_ratios([1.4, 1.4]).cause == "none"   # below min_n
    assert classify_ratios([]).n == 0


def test_triage_vetoes_waiting_on_degrading_node():
    """use_triage: a node whose ratio log climbs is never waited on even
    when the repair would land in time — the ladder jumps to migrate."""
    blocks, truth, nodes, plan = _cluster(n_blocks=60, k=3, slack=2.4,
                                          seed=33)
    deadline = plan.deadline_s
    from repro.runtime import FaultEvent
    # escalating faults on n0 make its ratio stream climb block over block
    ev = [FaultEvent(time=f * deadline, node="n0", factor=1.2)
          for f in (0.05, 0.12, 0.19, 0.26, 0.33, 0.40, 0.47)]
    crash = [NodeFailureEvent(time=0.55 * deadline, node="n0",
                              flavor="transient",
                              repair_s=0.02 * deadline)]
    base = dict(online=True, migrate=True, log_events=True)
    naive, _ = _run_both(plan, truth,
                         dict(base, recovery=RecoveryPolicy(max_waits=5)),
                         ev + crash, blocks)
    triaged, _ = _run_both(
        plan, truth,
        dict(base, recovery=RecoveryPolicy(max_waits=5, use_triage=True)),
        ev + crash, blocks)
    if not any(d.action == "wait" for d in naive.recoveries):
        pytest.skip("crash resolved without a wait even naively")
    tr = [d for d in triaged.recoveries if d.node == "n0"]
    assert tr and tr[0].action != "wait"
    assert tr[0].diagnosis is not None \
        and tr[0].diagnosis.cause == "degrading"


# --- validation -------------------------------------------------------------

def test_failure_event_validation():
    with pytest.raises(ValueError):
        NodeFailureEvent(time=-1.0, node="n0", repair_s=1.0)
    with pytest.raises(ValueError):
        NodeFailureEvent(time=0.0, node="n0", flavor="transient")  # no MTTR
    with pytest.raises(ValueError):
        NodeFailureEvent(time=0.0, node="n0", flavor="permanent",
                         repair_s=5.0)
    with pytest.raises(ValueError):
        NodeFailureEvent(time=0.0, node="n0", flavor="cosmic")
    with pytest.raises(ValueError):
        CheckpointModel(interval_s=0.0)
    with pytest.raises(ValueError):
        RecoveryPolicy(margin=1.0)
    with pytest.raises(ValueError):
        RecoveryPolicy(max_waits=-1)
    with pytest.raises(ValueError):
        RuntimeConfig(recovery=RecoveryPolicy())   # needs online=True
