"""Loop-aware HLO collective accounting: hand-checkable programs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.hloparse import _buffer_bytes, parse_collectives

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 1, reason="needs a device")


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def test_buffer_bytes():
    assert _buffer_bytes("f32[4,8]{1,0}") == 128
    assert _buffer_bytes("(bf16[2,2]{1,0}, s8[4]{0})") == 12
    assert _buffer_bytes("pred[]") == 1  # scalar: one element


def test_psum_outside_loop_counted_once():
    mesh = _mesh1()
    from jax.experimental.shard_map import shard_map

    def fn(x):
        return jax.lax.psum(x, "data")

    f = shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P())
    with mesh:
        comp = jax.jit(f).lower(jnp.ones((128,), jnp.float32)).compile()
    res = parse_collectives(comp.as_text())
    assert res["looped"]["all-reduce"] == res["raw"]["all-reduce"]
    assert res["looped"]["all-reduce"] >= 128 * 4


def test_psum_inside_scan_multiplied_by_trips():
    mesh = _mesh1()
    from jax.experimental.shard_map import shard_map

    trips = 7

    def inner(x):
        def body(c, _):
            return jax.lax.psum(c, "data") * 0.5, None
        out, _ = jax.lax.scan(body, x, None, length=trips)
        return out

    f = shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P())
    with mesh:
        comp = jax.jit(f).lower(jnp.ones((64,), jnp.float32)).compile()
    res = parse_collectives(comp.as_text())
    assert res["raw"]["all-reduce"] > 0
    assert res["looped"]["all-reduce"] == trips * res["raw"]["all-reduce"]
