"""Streamed SoA pipeline == object-based path, property-based.

The chunked dataset→plan pipeline (``repro.pipeline``) never constructs
per-block Python objects; this suite is the contract that its plans are
nonetheless IDENTICAL to the object path (``BlockEstimate`` → ``BlockInfo``
→ ``plan_dvfs`` / ``plan_cluster``) run on the same estimates — across
random chunk sizes (including boundaries that split a node's block set),
planners, deadline regimes, and cluster assignments — and that with
``sampler="exact"`` the estimates themselves are bit-identical to
``sample_blocks``.  Runs under the hypothesis compat shim.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (BlockArrays, BlockInfo, FrequencyLadder, PowerModel,
                        plan_dvfs, plan_dvo, plan_dvo_arrays, sample_blocks)
from repro.core import _reference as ref
from repro.cluster import NodeSpec, plan_cluster, plan_cluster_arrays
from repro.pipeline import (PipelineConfig, plan_estimates, stream_estimates,
                            stream_estimates_tokens, stream_plan,
                            synthetic_cost_chunks)


def _assert_plan_arrays_match_schedule(pa, plan):
    """PlanArrays (streamed) == SchedulePlan (object path), exactly."""
    assert pa.feasible == plan.feasible
    assert len(pa) == len(plan.blocks)
    for i, b in enumerate(plan.blocks):
        assert int(pa.index[i]) == b.index
        assert pa.rel_freq[i] == b.rel_freq
        assert pa.pred_time_s[i] == b.pred_time_s
        assert pa.pred_energy_j[i] == b.pred_energy_j


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 400),
    chunk=st.integers(1, 500),
    planner=st.sampled_from(["paper", "global"]),
    slack=st.floats(0.0, 1.0),
    z=st.floats(0.0, 2.0),
    seed=st.integers(0, 100),
)
def test_stream_plan_matches_object_path(n, chunk, planner, slack, z, seed):
    """Same estimates, object pipeline vs SoA pipeline: identical plans."""
    cfg = PipelineConfig(chunk_size=chunk, planner=planner)
    src = synthetic_cost_chunks(n, 24, z=z, seed=seed, chunk_size=chunk)
    est = stream_estimates(src, cfg)
    deadline = float(est.total.sum()) * (1.0 + slack) + 1e-6
    pa = stream_plan(est, deadline, cfg)
    blocks = est.to_block_arrays().to_blocks()
    _assert_plan_arrays_match_schedule(pa, plan_dvfs(blocks, deadline,
                                                     planner=planner))
    # and the PlanArrays view reconstructs the same SchedulePlan (totals
    # agree up to summation order: python sum vs pairwise np.sum)
    sp = pa.to_schedule_plan()
    assert sp.pred_total_energy == pytest.approx(pa.pred_total_energy,
                                                 rel=1e-12, abs=1e-9)


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(1, 200),
    chunk_a=st.integers(1, 250),
    chunk_b=st.integers(1, 250),
    seed=st.integers(0, 50),
)
def test_estimates_and_plans_invariant_to_chunk_size(n, chunk_a, chunk_b,
                                                     seed):
    """Chunk boundaries must never leak into estimates or plans."""
    ea = stream_estimates(
        synthetic_cost_chunks(n, 16, seed=seed, chunk_size=chunk_a),
        PipelineConfig(chunk_size=chunk_a))
    eb = stream_estimates(
        synthetic_cost_chunks(n, 16, seed=seed, chunk_size=chunk_b),
        PipelineConfig(chunk_size=chunk_b))
    assert np.array_equal(ea.total, eb.total)
    assert np.array_equal(ea.ci_low, eb.ci_low)
    assert np.array_equal(ea.ci_high, eb.ci_high)
    deadline = float(ea.total.sum()) * 1.2
    pa = stream_plan(ea, deadline, PipelineConfig())
    pb = stream_plan(eb, deadline, PipelineConfig())
    assert np.array_equal(pa.rel_freq, pb.rel_freq)
    assert np.array_equal(pa.pred_energy_j, pb.pred_energy_j)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 120),
    chunk=st.integers(1, 40),
    n_nodes=st.integers(1, 4),
    assignment=st.sampled_from(["auto", "lpt", "pack", "round_robin"]),
    slack=st.floats(0.05, 1.0),
    seed=st.integers(0, 40),
)
def test_stream_cluster_matches_object_path(n, chunk, n_nodes, assignment,
                                            slack, seed):
    """Cluster SoA path == object path on the same streamed estimates —
    chunk sizes deliberately smaller than node counts' strides, so chunk
    boundaries split every node's block set."""
    speeds = (1.0, 0.7, 1.3, 0.85)
    ladders = (FrequencyLadder(), FrequencyLadder(states=(0.5, 0.75, 1.0)))
    powers = (PowerModel(), PowerModel(p_full=95.0, p_idle=15.0, alpha=3.0))
    nodes = [NodeSpec(f"n{k}", speed=speeds[k % 4], ladder=ladders[k % 2],
                      power=powers[k % 2]) for k in range(n_nodes)]
    cfg = PipelineConfig(chunk_size=chunk)
    est = stream_estimates(
        synthetic_cost_chunks(n, 16, seed=seed, chunk_size=chunk), cfg)
    worst = float(est.total.sum()) / min(nd.speed for nd in nodes)
    deadline = worst * (1.0 + slack) + 1e-6
    cpa = plan_estimates(est, deadline, cfg, nodes=nodes,
                         assignment=assignment)
    blocks = est.to_block_arrays().to_blocks()
    obj = plan_cluster(blocks, nodes, deadline, assignment=assignment)
    got = cpa.to_cluster_plan()
    assert got.feasible == obj.feasible
    assert cpa.pred_total_energy == pytest.approx(obj.pred_total_energy,
                                                  abs=1e-9)
    for a_np, b_np in zip(got.node_plans, obj.node_plans):
        assert a_np.node.name == b_np.node.name
        assert len(a_np.blocks) == len(b_np.blocks)
        for a, b in zip(a_np.blocks, b_np.blocks):
            assert a.index == b.index
            assert a.rel_freq == b.rel_freq
            assert a.pred_time_s == b.pred_time_s
            assert a.pred_energy_j == b.pred_energy_j


@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(1, 60),
    chunk=st.integers(1, 70),
    seed=st.integers(0, 30),
)
def test_exact_sampler_bit_identical_to_sample_blocks(n, chunk, seed):
    """sampler="exact": the SoA estimates ARE sample_blocks', bit for bit."""
    rng = np.random.default_rng(seed)
    costs = rng.lognormal(0.0, 0.6, (n, 120))
    cfg = PipelineConfig(chunk_size=chunk, sampler="exact", seed=seed)
    est = stream_estimates(costs, cfg)
    want = sample_blocks(list(costs), seed=seed)
    assert est.to_block_estimates() == want


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 250),
    slack=st.floats(0.0, 0.25),
    seed=st.integers(0, 60),
)
def test_tight_deadline_scan_matches_reference(n, slack, seed):
    """Budget-binding regime (kills dominate): the array-level scan must
    reproduce the loop reference exactly — this is the regime the old
    implementation handed to a per-step python tail."""
    rng = np.random.default_rng(seed)
    costs = rng.lognormal(0.0, 0.8, n) * 4.0
    blocks = [BlockInfo(i, float(c), util=float(rng.uniform(0.3, 1.0)))
              for i, c in enumerate(costs)]
    deadline = float(costs.sum()) * (1.0 + slack)
    p = plan_dvfs(blocks, deadline, planner="global")
    q = ref.plan_dvfs_reference(blocks, deadline, planner="global")
    assert p.feasible == q.feasible
    for a, b in zip(p.blocks, q.blocks):
        assert a.rel_freq == b.rel_freq
        assert a.pred_time_s == b.pred_time_s
        assert abs(a.pred_energy_j - b.pred_energy_j) <= 1e-9


def test_sampler_keys_decorrelated_from_generator_stream():
    """Source and sampler share one seed in the natural call; the sampler's
    selection keys must live in a different hash domain, or 'pick the k
    smallest keys' silently becomes 'pick the k cheapest records' and every
    estimate is biased low (caught in review: ratio was ~0.15)."""
    chunks = list(synthetic_cost_chunks(800, 200, z=1.0, seed=0,
                                        chunk_size=200))
    true_totals = np.concatenate([c["costs"].sum(axis=1) for c in chunks])
    est = stream_estimates(iter(chunks), PipelineConfig(chunk_size=200,
                                                        seed=0))
    ratio = float((est.total / true_totals).mean())
    assert 0.85 < ratio < 1.15


def test_cluster_node_plan_feasibility_is_per_node():
    """An infeasible node's PlanArrays must not claim feasible=True."""
    est = stream_estimates(synthetic_cost_chunks(30, 16, seed=6),
                           PipelineConfig())
    nodes = [NodeSpec("n0", speed=1.0), NodeSpec("n1", speed=1.0)]
    # deadline far below any node's share: nothing is feasible
    cpa = plan_cluster_arrays(est.to_block_arrays(), nodes,
                              float(est.total.sum()) * 1e-3,
                              assignment="round_robin")
    assert not cpa.feasible
    assert all(not np_.plan.feasible for np_ in cpa.node_plans)


def test_dvo_arrays_matches_object_dvo():
    est = stream_estimates(synthetic_cost_chunks(64, 16, seed=2),
                           PipelineConfig())
    ba = est.to_block_arrays()
    deadline = float(est.total.sum()) * 1.5
    pa = plan_dvo_arrays(ba, deadline)
    _assert_plan_arrays_match_schedule(pa, plan_dvo(ba.to_blocks(), deadline))


def test_token_pipeline_chunk_invariant_and_planable():
    """Tokens → batched stats kernel → estimates → plan, end to end."""
    from repro.data import BlockDataset
    ds = BlockDataset(n_blocks=10, records_per_block=48, max_len=32, seed=9)
    e1 = stream_estimates_tokens(ds.iter_token_chunks(3))
    e2 = stream_estimates_tokens(ds.iter_token_chunks(10))
    assert np.array_equal(e1.total, e2.total)
    assert np.isfinite(e1.total).all()
    assert np.all(e1.ci_high >= e1.total) and np.all(e1.ci_low <= e1.total)
    pa = stream_plan(e1, float(e1.total.sum()) * 1.3, PipelineConfig())
    assert pa.feasible
    assert len(pa) == 10


def test_stats_soa_matches_object_stats():
    """BlockDataset.stats_soa (batched kernel, SoA) == stats(i) objects."""
    from repro.data import BlockDataset
    ds = BlockDataset(n_blocks=6, records_per_block=40, max_len=24, seed=4)
    soa = ds.stats_soa(chunk_size=4)
    for i in range(ds.n_blocks):
        s = ds.stats(i)
        assert soa["records"][i] == s.records
        assert soa["tokens"][i] == s.tokens
        assert soa["tokens_padded"][i] == s.tokens_padded
        assert soa["matches"][i] == s.matches
        assert soa["selected"][i] == s.selected


def test_block_arrays_roundtrip_preserves_blocks():
    """from_blocks -> to_blocks is the identity (incl. rooflines)."""
    from repro.core import BlockInfo, RooflineTimeModel
    roof = RooflineTimeModel.from_counts(flops=1e12, hbm_bytes=2e10,
                                         coll_bytes=1e8)
    blocks = [BlockInfo(3, 1.5, est_rel_halfwidth=0.02, util=0.7,
                        roofline=roof),
              BlockInfo(7, 0.5, util=0.4)]
    back = BlockArrays.from_blocks(blocks).to_blocks()
    assert back == blocks


def test_plan_arrays_is_soa_not_objects():
    """The streamed plan holds arrays; BlockPlan objects only on demand."""
    est = stream_estimates(synthetic_cost_chunks(128, 16, seed=0),
                           PipelineConfig())
    pa = stream_plan(est, float(est.total.sum()) * 1.4, PipelineConfig())
    assert isinstance(pa.rel_freq, np.ndarray)
    assert isinstance(pa.pred_energy_j, np.ndarray)
    blocks = pa.to_blocks()
    assert len(blocks) == 128
    assert blocks[0].rel_freq == pa.rel_freq[0]


# --- calibrated rooflines in the stream path ---------------------------------

def _cluster_plans_equal(cpa, obj):
    got = cpa.to_cluster_plan()
    assert got.feasible == obj.feasible
    for a_np, b_np in zip(got.node_plans, obj.node_plans):
        assert a_np.node.name == b_np.node.name
        assert len(a_np.blocks) == len(b_np.blocks)
        for a, b in zip(a_np.blocks, b_np.blocks):
            assert a.index == b.index
            assert a.rel_freq == b.rel_freq
            assert a.pred_time_s == b.pred_time_s
            assert a.pred_energy_j == b.pred_energy_j


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(2, 80),
    chunk=st.integers(1, 40),
    beta=st.floats(0.0, 0.6),
    slack=st.floats(0.1, 1.0),
    seed=st.integers(0, 30),
)
def test_stream_calibrated_rooflines_match_object_path(n, chunk, beta,
                                                       slack, seed):
    """``PipelineConfig(calibration=CostFit)`` == object path with
    ``CostFit.roofline()`` stamped block by block — the fitted memory-bound
    fraction reaches streamed plans exactly as it reaches object plans."""
    import dataclasses as dc
    from repro.calibrate import fit_cost_model

    rng = np.random.default_rng(seed)
    # observations exercising the max-form kink at two frequencies, so the
    # fit recovers a nonzero memory-bound fraction when beta > 0
    rec = rng.uniform(100, 2000, 24)
    f = np.where(np.arange(24) % 2 == 0, 1.0, 0.6)
    wall = rec * 3e-4 * np.maximum((1.0 - beta) / f, 1.0)
    cf = fit_cost_model(rec, f, wall)

    nodes = [NodeSpec("a", speed=1.0), NodeSpec("b", speed=0.8)]
    cfg = PipelineConfig(chunk_size=chunk, calibration=cf)
    est = stream_estimates(
        synthetic_cost_chunks(n, 16, seed=seed, chunk_size=chunk), cfg)
    deadline = float(est.total.sum()) / 0.8 * (1.0 + slack) + 1e-6
    cpa = plan_estimates(est, deadline, cfg, nodes=nodes)

    # independent object path: scalar CostFit.roofline() per block
    blocks = [dc.replace(b, roofline=cf.roofline(b.records))
              for b in est.to_block_arrays().to_blocks()]
    obj = plan_cluster(blocks, nodes, deadline)
    _cluster_plans_equal(cpa, obj)


def test_stream_calibration_trace_calibrates_nodes():
    """``PipelineConfig(calibration=CounterTrace)`` == planning against
    ``calibrate_nodes(nodes, trace)`` — the streamed entry to the
    estimate->plan->measure loop."""
    from repro.calibrate import calibrate_nodes, synthetic_trace

    tr_parts = [synthetic_trace(nm, PowerModel(), speed=s, n_samples=60,
                                seed=i)
                for i, (nm, s) in enumerate([("a", 1.2), ("b", 0.9)])]
    from repro.calibrate import CounterTrace
    tr = CounterTrace.concat(tr_parts)
    nodes = [NodeSpec("a", speed=1.0), NodeSpec("b", speed=1.0)]

    est = stream_estimates(synthetic_cost_chunks(40, 16, seed=3),
                           PipelineConfig())
    deadline = float(est.total.sum()) * 1.2
    cfg = PipelineConfig(calibration=tr)
    cpa = plan_estimates(est, deadline, cfg, nodes=nodes)
    obj = plan_cluster(est.to_block_arrays().to_blocks(),
                       calibrate_nodes(nodes, tr), deadline)
    _cluster_plans_equal(cpa, obj)


def test_token_estimates_calibrated_pricing():
    """A CostFit replaces the linear token model: totals are
    records * cost_per_record, nothing is sampled, and the chunked plan
    carries the fit's roofline shape."""
    from repro.calibrate.fit import CostFit

    cf = CostFit(cost_per_record=2e-4, mem_fraction=0.4, rmse_s=1e-3,
                 n_samples=24)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 50, (6, 32, 8)).astype(np.int32)
    cfg = PipelineConfig(calibration=cf)
    est = stream_estimates_tokens([(0, toks)], cfg)
    assert np.array_equal(est.total, np.full(6, 32 * 2e-4))
    assert int(est.n_sampled.sum()) == 0
    # and the planner sees the calibrated zero-cost down-clock floor
    pa = plan_estimates(est, float(est.total.sum()) * 1.1, cfg)
    ba = est.to_block_arrays(roofline=cf.roofline_arrays(est.n_records))
    assert ba.roofline is not None and bool(ba.roofline.has.all())
    zero_cost = ba.roofline.t_comp / ba.roofline.t_mem
    assert np.allclose(zero_cost, 1.0 - cf.mem_fraction)
    assert pa.feasible


def test_pipeline_config_rejects_unknown_calibration():
    with pytest.raises(TypeError, match="calibration"):
        plan_estimates(stream_estimates(synthetic_cost_chunks(4, 8, seed=0),
                                        PipelineConfig()),
                       100.0, PipelineConfig(calibration=object()))
