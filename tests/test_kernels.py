"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(rng, shape, dtype):
    x = rng.normal(0, 1, shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("s,d,bq,bk", [(128, 64, 64, 64), (256, 32, 128, 64)])
def test_flash_attention_sweep(dtype, hq, hkv, s, d, bq, bk):
    rng = np.random.default_rng(hash((hq, hkv, s, d)) % 2**31)
    q = _rand(rng, (2, hq, s, d), dtype)
    k = _rand(rng, (2, hkv, s, d), dtype)
    v = _rand(rng, (2, hkv, s, d), dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("swa", [32, 128])
def test_flash_attention_swa(swa):
    rng = np.random.default_rng(1)
    q = _rand(rng, (1, 2, 256, 32), jnp.float32)
    k = _rand(rng, (1, 2, 256, 32), jnp.float32)
    v = _rand(rng, (1, 2, 256, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, swa_window=swa, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, swa_window=swa)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(2)
    q = _rand(rng, (1, 2, 128, 32), jnp.float32)
    k = _rand(rng, (1, 2, 128, 32), jnp.float32)
    v = _rand(rng, (1, 2, 128, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,p,n,chunk", [(128, 16, 32, 32), (256, 32, 16, 64),
                                         (64, 8, 8, 64)])
def test_ssd_scan_sweep(dtype, s, p, n, chunk):
    rng = np.random.default_rng(hash((s, p, n)) % 2**31)
    bh = 3
    x = _rand(rng, (bh, s, p), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (bh, s)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 1, (bh,)), jnp.float32)
    bm = _rand(rng, (bh, s, n), dtype)
    cm = _rand(rng, (bh, s, n), dtype)
    y = ops.ssd_scan(x, dt, a_log, bm, cm, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(x, dt, a_log, bm, cm)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("rows,length,br", [(128, 64, 32), (256, 32, 128),
                                            (64, 96, 64)])
def test_block_stats_sweep(rows, length, br):
    rng = np.random.default_rng(hash((rows, length)) % 2**31)
    toks = rng.integers(0, 50, (rows, length)).astype(np.int32)
    # plant some patterns
    for r in range(0, rows, 7):
        toks[r, : 3] = (17, 23, 5)
    got = ops.block_stats(jnp.asarray(toks), (17, 23, 5), block_rows=br,
                          interpret=True)
    want = ref.block_stats_ref(jnp.asarray(toks), (17, 23, 5))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    assert np.asarray(got)[1] >= rows // 7  # planted matches found


@pytest.mark.parametrize("rows,length,br", [(100, 64, 32), (7, 16, 128),
                                            (257, 48, 64), (130, 32, 128)])
def test_block_stats_ragged_rows(rows, length, br):
    """Row counts that do NOT divide the tile: final tile padded + masked."""
    rng = np.random.default_rng(hash((rows, length, br)) % 2**31)
    toks = rng.integers(0, 50, (rows, length)).astype(np.int32)
    for r in range(0, rows, 5):
        toks[r, :3] = (17, 23, 5)
    got = ops.block_stats(jnp.asarray(toks), (17, 23, 5), block_rows=br,
                          interpret=True)
    want = ref.block_stats_ref(jnp.asarray(toks), (17, 23, 5))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("nb,rmax,length,br", [(12, 96, 40, 32),
                                               (5, 64, 24, 64),
                                               (3, 130, 32, 64)])
def test_block_stats_batched_ragged(nb, rmax, length, br):
    """One (n_blocks, row_tiles) dispatch == per-block oracle; pattern hits
    planted in PAD rows must be masked out of the stats."""
    rng = np.random.default_rng(hash((nb, rmax, length)) % 2**31)
    lens = rng.integers(1, rmax + 1, nb)
    toks = np.zeros((nb, rmax, length), np.int32)
    for b in range(nb):
        toks[b, :lens[b]] = rng.integers(0, 50, (lens[b], length))
        toks[b, 0, :3] = (17, 23, 5)
        toks[b, lens[b]:, :3] = (17, 23, 5)  # poison the padding
    got = ops.block_stats_batched(jnp.asarray(toks), jnp.asarray(lens),
                                  (17, 23, 5), block_rows=br, interpret=True)
    want = ref.block_stats_batched_ref(jnp.asarray(toks), lens, (17, 23, 5))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    assert np.asarray(got).shape == (nb, 3)
    assert all(np.asarray(got)[:, 1] >= 1)  # real planted hits survive


def test_block_stats_batched_full_blocks():
    """lengths=None means every row is real."""
    rng = np.random.default_rng(11)
    toks = jnp.asarray(rng.integers(0, 50, (6, 64, 32)), jnp.int32)
    got = ops.block_stats_batched(toks, None, (17, 23, 5), block_rows=32,
                                  interpret=True)
    want = ref.block_stats_batched_ref(toks, None, (17, 23, 5))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("nb,r,length,br", [(1, 5, 24, 128), (1, 1, 16, 128),
                                            (4, 3, 24, 128), (1, 128, 24, 32)])
def test_block_stats_batched_small_shapes(nb, r, length, br):
    """n_rows < tile and n_blocks == 1: the ragged masking path must be
    exact when the whole block fits inside one (possibly padded) tile."""
    rng = np.random.default_rng(hash((nb, r, length)) % 2**31)
    toks = rng.integers(0, 50, (nb, r, length)).astype(np.int32)
    toks[:, 0, :3] = (17, 23, 5)
    got = ops.block_stats_batched(jnp.asarray(toks), None, (17, 23, 5),
                                  block_rows=br, interpret=True)
    want = ref.block_stats_batched_ref(jnp.asarray(toks), None, (17, 23, 5))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    assert np.asarray(got).shape == (nb, 3)


def test_block_stats_batched_single_block_ragged_length():
    """n_blocks == 1 with a length < R: pad rows masked, poison ignored."""
    rng = np.random.default_rng(3)
    toks = np.zeros((1, 40, 24), np.int32)
    toks[0, :17] = rng.integers(0, 50, (17, 24))
    toks[0, 0, :3] = (17, 23, 5)
    toks[0, 17:, :3] = (17, 23, 5)  # poison the padding
    got = ops.block_stats_batched(jnp.asarray(toks), jnp.asarray([17]),
                                  (17, 23, 5), block_rows=16, interpret=True)
    want = ref.block_stats_batched_ref(jnp.asarray(toks), [17], (17, 23, 5))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_block_stats_pattern_longer_than_row():
    """A pattern that cannot fit in a row yields zero matches, not a crash."""
    rng = np.random.default_rng(4)
    toks = rng.integers(1, 50, (8, 2)).astype(np.int32)
    got = np.asarray(ops.block_stats(jnp.asarray(toks), (17, 23, 5),
                                     interpret=True))
    assert got[1] == 0.0
    assert got[0] == float((toks != 0).sum())
    bat = np.asarray(ops.block_stats_batched(
        jnp.asarray(toks[None]), None, (17, 23, 5), interpret=True))
    assert bat[0, 1] == 0.0
