"""Counterfactual observability: replay ledgers, run-diff, watchdog.

The contract on top of the deterministic engines:

  (a) exact Δ-ledgers — every ``profile_mechanisms`` row's five channel
      deltas plus the rational-space residual ``math.fsum`` BITWISE to
      the difference of the two reports' own totals, on randomized
      scenarios; mechanisms that were already off replay to all-zero
      rows; the DVFS ablation on the everything-on scenario reproduces
      the paper headline (f_max pays strictly more busy energy);
  (b) run-diff — ``diff_runs(r, r)`` is empty for any report; ablations
      produce attributed non-empty diffs; added/dropped round-trip when
      the arguments swap (shedding exercises real add/drop sets);
  (c) watchdog — the alert stream is bitwise-identical scalar vs vector
      and across two runs; ``deadline_risk`` alerts reach the replanner
      hook and nothing else does;
  (d) flight-recorder guard — replay-grade tools (``build_spans``,
      ``explain_*``) refuse ring/off logs loudly, naming the mode and
      drop count, while ``diff_runs`` degrades to report-level rollups;
  (e) exporter validation — ``validate_prometheus`` passes real
      expositions and rejects malformed ones;
  (f) bench history — ``benchmarks.history`` appends schema-stamped
      entries and flags trend regressions against the median baseline.
"""
import dataclasses
import json
import math

import pytest
from _hypothesis_compat import given, settings, st
from test_runtime_vector import _everything_on_parts, _scenario

from repro import obs
from repro.cluster.controller import OnlineReplanner
from repro.serving import run_serving, serving_scenario

CHANNELS = ("busy_j", "idle_j", "switch_j", "wire_j", "failed_j")


def _cf_scenario(seed=None, parts=None):
    plan, truth, cfg, events, blocks = parts if parts else _scenario(seed)
    return obs.Scenario(plan=plan, truth=truth, config=cfg,
                        events=tuple(events), est_blocks=blocks)


def _assert_reconciled(row):
    parts = [row["d_" + c] for c in CHANNELS] + [row["residual_j"]]
    assert math.fsum(parts) == row["d_total_j"], row["mechanism"]


# ------------------------------------------------------- (a) exact Δ-ledgers

@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_delta_ledger_reconciles_exactly(seed):
    sc = _cf_scenario(seed)
    for row in obs.profile_mechanisms(sc, engines=("vector",)):
        _assert_reconciled(row)
        if not row["changed"]:   # identity replay: every delta exactly zero
            assert row["d_total_j"] == 0.0
            assert row["d_misses"] == 0
            assert row["d_slack_s"] == 0.0


def test_everything_on_both_engines_and_paper_headline():
    sc = _cf_scenario(parts=_everything_on_parts(seed=7))
    rows = obs.profile_mechanisms(sc, engines=("vector", "scalar"))
    for row in rows:
        _assert_reconciled(row)
    dvfs = next(r for r in rows if r["mechanism"] == "dvfs")
    # the paper's claim as a counterfactual on this very run: pinning
    # every node at f_max pays strictly more busy energy
    assert dvfs["changed"]
    assert dvfs["d_busy_j"] > 0.0


def test_neutralize_dvfs_pins_every_ladder():
    sc = _cf_scenario(parts=_everything_on_parts(seed=7))
    neutral, changed = obs.neutralize(sc, "dvfs")
    assert changed
    cpa = neutral.plan.to_arrays()
    assert all(npa.node.ladder.states == (1.0,) for npa in cpa.node_plans)
    # neutralizing the already-pinned scenario is a no-op
    again, changed2 = obs.neutralize(neutral, "dvfs")
    assert not changed2 and again is neutral


def test_neutralize_rejects_unknown_mechanism():
    sc = _cf_scenario(seed=3)
    with pytest.raises(ValueError, match="unknown mechanism"):
        obs.neutralize(sc, "gremlins")


def test_scenario_rejects_stateful_config():
    plan, truth, cfg, events, blocks = _scenario(3)
    bad = dataclasses.replace(cfg, metrics=obs.StreamingMetrics())
    with pytest.raises(ValueError, match="metrics"):
        obs.Scenario(plan=plan, truth=truth, config=bad)


# ------------------------------------------------------------- (b) run-diff

def test_diff_identity_is_empty():
    sc = _cf_scenario(parts=_everything_on_parts(seed=7))
    a = sc.run(engine="vector")
    b = sc.run(engine="vector")
    d = obs.diff_runs(a, b)
    assert d.empty
    assert d.spans_aligned


def test_diff_attributes_migration_ablation():
    sc = _cf_scenario(parts=_everything_on_parts(seed=7))
    base = sc.run(engine="vector")
    abl = obs.ablate(sc, "migration", engines=("vector",))
    d = obs.diff_runs(base, abl)
    assert not d.empty
    assert d.blocks or d.moved
    assert any(m["mechanism"] == "migration" for m in d.mechanisms)
    # swapped arguments negate the totals and swap the move endpoints
    r = obs.diff_runs(abl, base)
    assert r.totals["d_total_j"] == -d.totals["d_total_j"]
    assert sorted((i, b, a) for i, a, b in d.moved) == sorted(r.moved)


def _shedding_serving_scenario():
    """First seeded serving scenario whose guarded run actually sheds."""
    for seed in range(40):
        ss = serving_scenario(seed)
        if not (ss.serving.admission or ss.serving.shedding):
            continue
        rep = run_serving(ss.plan, ss.truth, ss.arrivals, config=ss.config(),
                          serving=ss.serving, arrival_truth=ss.arrival_truth,
                          events=ss.events, est_blocks=ss.blocks,
                          engine="vector")
        if rep.n_shed > 0:
            return ss
    pytest.skip("no shedding serving scenario in the seed sweep")


def test_diff_add_drop_round_trip_under_shedding():
    ss = _shedding_serving_scenario()
    sc = obs.Scenario(plan=ss.plan, truth=ss.truth, config=ss.config(),
                      events=tuple(ss.events), est_blocks=ss.blocks,
                      arrivals=ss.arrivals, serving=ss.serving,
                      arrival_truth=ss.arrival_truth)
    assert sc.is_serving
    guarded = sc.run(engine="vector")
    opened = obs.ablate(sc, "admission", engines=("vector",))
    d = obs.diff_runs(guarded, opened)
    # accept-all executes block work the guarded run shed or rejected
    assert d.added
    assert not d.empty
    # jobs changed status (shed/rejected -> accepted) rather than appearing
    assert d.jobs and not (d.jobs_added or d.jobs_dropped)
    assert d.tenants
    # round-trip: swapping the arguments swaps added and dropped exactly
    r = obs.diff_runs(opened, guarded)
    assert r.dropped == d.added
    assert r.added == d.dropped


def test_profile_mechanisms_serving_tenant_deltas():
    ss = _shedding_serving_scenario()
    sc = obs.Scenario(plan=ss.plan, truth=ss.truth, config=ss.config(),
                      events=tuple(ss.events), est_blocks=ss.blocks,
                      arrivals=ss.arrivals, serving=ss.serving,
                      arrival_truth=ss.arrival_truth)
    rows = obs.profile_mechanisms(sc, mechanisms=["admission"],
                                  engines=("vector",))
    (row,) = rows
    _assert_reconciled(row)
    assert row["changed"]
    assert row["tenants"]    # accept-all shifts per-tenant SLO outcomes


# ------------------------------------------------------------- (c) watchdog

def _watch(parts, engine):
    plan, truth, cfg, events, blocks = parts
    mx = obs.StreamingMetrics()
    wd = obs.Watchdog(obs.standard_rules(
        plan.deadline_s, energy_budget_j=30_000.0,
        shed_budget_hz=0.5)).attach(mx)
    from repro.runtime import run_cluster
    run_cluster(plan, truth, config=dataclasses.replace(cfg, metrics=mx),
                events=events, est_blocks=blocks, engine=engine)
    return wd.alerts


def test_watchdog_bitwise_identical_across_engines_and_runs():
    parts = _everything_on_parts(seed=7)
    a = _watch(parts, "vector")
    b = _watch(parts, "scalar")
    c = _watch(parts, "vector")
    assert a          # the tight seed-7 scenario does fire
    assert a == b     # scalar vs vector, bitwise (Alert is all-float)
    assert a == c     # two-run determinism


def test_watchdog_rule_validation():
    with pytest.raises(ValueError, match="unknown signal"):
        obs.Rule("bad", "vibes", 1.0, 5.0)
    with pytest.raises(ValueError, match="fast_s"):
        obs.Rule("bad", "deadline_risk", 5.0, 1.0)


def test_watchdog_dispatch_and_replanner_hook():
    fired, replanned = [], []

    class _Stub:
        def on_alert(self, alert):
            replanned.append(alert)
            return 0

    parts = _everything_on_parts(seed=7)
    plan, truth, cfg, events, blocks = parts
    mx = obs.StreamingMetrics()
    wd = obs.Watchdog(obs.standard_rules(plan.deadline_s),
                      on_fire=fired.append, replanner=_Stub()).attach(mx)
    from repro.runtime import run_cluster
    run_cluster(plan, truth, config=dataclasses.replace(cfg, metrics=mx),
                events=events, est_blocks=blocks, engine="vector")
    assert list(wd.alerts) == fired
    # only deadline_risk alerts reach the replanner
    assert replanned == [a for a in fired if a.signal == "deadline_risk"]
    # a second poll re-evaluates but never re-fires the same alert
    n = len(fired)
    assert wd.poll() == wd.alerts
    assert len(fired) == n


def test_online_replanner_on_alert():
    plan, truth, cfg, events, blocks = _everything_on_parts(seed=7)
    ctl = OnlineReplanner(plan, est_blocks=blocks)
    risk = obs.Alert(time=1.0, rule="deadline-risk", signal="deadline_risk",
                     window_s=1.0, severity="page", value=2.0,
                     slow_value=2.0)
    n = ctl.on_alert(risk)
    assert isinstance(n, int) and n >= 0
    # non-risk signals are ignored outright
    cap = dataclasses.replace(risk, rule="cap", signal="cap_pressure")
    assert ctl.on_alert(cap) == 0


# ------------------------------------------------- (d) flight-recorder guard

@pytest.mark.parametrize("mode", ["ring:64", "off"])
def test_replay_tools_refuse_truncated_logs(mode):
    plan, truth, cfg, events, blocks = _everything_on_parts(seed=7)
    cfg = dataclasses.replace(cfg, event_log=mode)
    from repro.runtime import run_cluster
    rep = run_cluster(plan, truth, config=cfg, events=events,
                      est_blocks=blocks, engine="vector")
    assert rep.event_log_mode == mode
    for tool in (obs.build_spans,
                 lambda r: obs.explain_miss(r, node="n0"),
                 obs.explain_energy):
        with pytest.raises(ValueError) as err:
            tool(rep)
        assert mode in str(err.value)
        assert "events_dropped" in str(err.value)
    # diff_runs degrades to report-level rollups instead of raising
    d = obs.diff_runs(rep, rep)
    assert d.empty
    assert not d.spans_aligned


def test_full_log_report_still_replays():
    plan, truth, cfg, events, blocks = _everything_on_parts(seed=7)
    from repro.runtime import run_cluster
    rep = run_cluster(plan, truth, config=cfg, events=events,
                      est_blocks=blocks, engine="vector")
    assert rep.event_log_mode == "full"
    obs.require_full_log(rep)        # no raise
    assert obs.build_spans(rep)      # report accepted directly


# ----------------------------------------------- (e) prometheus validation

def test_validate_prometheus_accepts_real_exposition():
    plan, truth, cfg, events, blocks = _everything_on_parts(seed=7)
    mx = obs.StreamingMetrics()
    from repro.runtime import run_cluster
    run_cluster(plan, truth, config=dataclasses.replace(cfg, metrics=mx),
                events=events, est_blocks=blocks, engine="vector")
    text = obs.to_prometheus(mx)
    assert obs.validate_prometheus(text) == []


GOOD = ("# HELP repro_x Stuff.\n"
        "# TYPE repro_x counter\n"
        'repro_x{node="n0"} 1.0\n')


@pytest.mark.parametrize("text,needle", [
    (GOOD[:-1], "newline"),                               # no trailing \n
    ("# TYPE repro_x counter\nrepro_x 1\n", "HELP"),      # TYPE sans HELP
    (GOOD + "# TYPE repro_x gauge\n", "duplicate"),       # re-declared
    (GOOD.replace("counter", "accumulator"), "type"),     # bad kind
    (GOOD + "repro_y 1.0\n", "undeclared"),               # sample sans TYPE
    (GOOD.replace(' 1.0', ' -1.0'), "negative"),          # counter < 0
    (GOOD + 'repro_x{node="n0"} 2.0\n', "duplicate"),     # duplicate series
    (GOOD.replace('"n0"', '"n\\q0"'), "escape"),          # bad label escape
    (GOOD.replace(" 1.0", " banana"), "value"),           # unparsable value
])
def test_validate_prometheus_rejects(text, needle):
    problems = obs.validate_prometheus(text)
    assert problems
    assert any(needle.lower() in p.lower() for p in problems), problems


# ------------------------------------------------------- (f) bench history

def _blob(bps, schema=6):
    return {"schema_version": schema, "git_sha": "deadbee",
            "obs_cf": [{"scenario": "watchdog", "n": 100,
                        "blocks_per_s": bps}]}


def test_history_append_and_trend_check(tmp_path):
    from benchmarks import history

    hist = str(tmp_path / "history.jsonl")
    bench = tmp_path / "bench.json"

    # empty history: nothing to check
    assert history.check(hist) == 0

    bench.write_text(json.dumps(_blob(1000.0)))
    entry = history.append(str(bench), hist)
    assert entry["schema_version"] == 6
    assert entry["metrics"] == {
        "obs_cf/n=100,scenario=watchdog": 1000.0}
    # single entry: no baseline yet, passes vacuously
    assert history.check(hist) == 0

    # steady runs pass against the median baseline
    history.append(str(bench), hist)
    history.append(str(bench), hist)
    assert history.check(hist) == 0

    # a big drop beyond the obs_cf threshold (0.3) fails the trend check
    bench.write_text(json.dumps(_blob(100.0)))
    history.append(str(bench), hist)
    assert history.check(hist) == 1

    # entries from another schema version are not compared at all
    bench.write_text(json.dumps(_blob(100.0, schema=7)))
    history.append(str(bench), hist)
    assert history.check(hist) == 0
