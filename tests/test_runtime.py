"""Invariant suite for the event-driven cluster runtime (``repro.runtime``).

Property-based via the hypothesis compat shim.  The contract:

  (a) with no faults, no cap, and actuation latency 0 the engine reproduces
      the block-boundary loop (``simulate_cluster_reference``) BIT-FOR-BIT
      — per-node busy seconds, energies, frequencies, report equality —
      from the same plan, static and online alike;
  (b) partial-block accounting is exact: a block split across k
      frequencies costs the sum of its segments' times/energies as priced
      by ``block_time_table``/``busy_energy_table``, verified from event
      timestamps alone;
  (c) migration never moves an in-flight block and never pushes a
      previously-feasible node past the deadline;
  (d) with ``power_cap_w`` set, the instantaneous cluster draw
      (reconstructed independently from the event log) never exceeds the
      cap at any event timestamp;
  (e) a fixed scenario is deterministic: two runs produce identical event
      logs and reports.
"""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import BlockInfo, FrequencyLadder
from repro.core.scheduler import block_time_table, busy_energy_table
from repro.cluster import (NodeSpec, SlowdownEvent, assign_blocks,
                           plan_cluster, simulate_cluster,
                           simulate_cluster_reference)
from repro.cluster.planner import BlockPlan, ClusterPlan, NodePlan
from repro.runtime import (ActuationModel, FaultEvent, RuntimeConfig,
                           run_cluster)

DEEP = FrequencyLadder(
    states=tuple(round(f, 2) for f in np.arange(0.35, 1.001, 0.05)))
SPEED_SETS = {1: (1.0,), 2: (1.0, 0.7), 3: (1.0, 0.7, 1.3),
              4: (1.0, 0.7, 1.3, 0.9)}


def _blocks(costs):
    return [BlockInfo(i, float(c)) for i, c in enumerate(costs)]


def _nodes(n):
    return [NodeSpec(f"n{k}", speed=s, ladder=DEEP)
            for k, s in enumerate(SPEED_SETS[n])]


def _deadline(blocks, nodes, slack):
    rr = assign_blocks(blocks, nodes, strategy="round_robin")
    return max(sum(b.est_time_fmax for b in g) / n.speed
               for g, n in zip(rr, nodes)) * slack


# --- (a) bit-for-bit compatibility with the block-boundary loop -------------

@settings(max_examples=30, deadline=None)
@given(
    costs=st.lists(st.floats(0.5, 20.0), min_size=2, max_size=20),
    slack=st.floats(1.05, 2.0),
    n_nodes=st.integers(1, 4),
    online=st.booleans(),
    fault=st.booleans(),
)
def test_engine_reproduces_blockloop_bitforbit(costs, slack, n_nodes,
                                               online, fault):
    blocks = _blocks(costs)
    nodes = _nodes(n_nodes)
    plan = plan_cluster(blocks, nodes, _deadline(blocks, nodes, slack))
    events = [SlowdownEvent("n0", after_block=1, factor=1.7)] if fault else []
    kw = dict(online=online, events=events, ewma_alpha=0.5,
              replan_threshold=0.1)
    assert simulate_cluster(plan, blocks, **kw) \
        == simulate_cluster_reference(plan, blocks, **kw)


def test_engine_consumes_plan_arrays_directly():
    """ClusterPlanArrays in == ClusterPlan in (the SoA path needs no object
    materialization on the static run)."""
    blocks = _blocks([3.0, 7.0, 1.0, 5.0, 2.0, 4.0])
    nodes = _nodes(2)
    plan = plan_cluster(blocks, nodes, _deadline(blocks, nodes, 1.4))
    from repro.core.soa import BlockArrays
    r_obj = run_cluster(plan, blocks)
    r_soa = run_cluster(plan.to_arrays(), BlockArrays.from_blocks(blocks))
    assert r_obj == r_soa


# --- (b) partial-block accounting is exact ----------------------------------

def _single_node_plan(node, ests, freqs, deadline):
    bps = tuple(BlockPlan(i, deadline / len(ests), f,
                          node.block_time(BlockInfo(i, e), f),
                          node.block_energy(BlockInfo(i, e),
                                            node.block_time(BlockInfo(i, e), f),
                                            f))
                for i, (e, f) in enumerate(zip(ests, freqs)))
    return ClusterPlan("cluster", deadline, (NodePlan(node, bps),), True)


def test_midblock_switch_accounting_matches_tables():
    """Actuation latency forces block 1 to launch at block 0's frequency and
    switch mid-block; both segments must price off the planner's own
    time/energy tables, checked from event timestamps."""
    node = NodeSpec("n0", ladder=FrequencyLadder(states=(0.5, 1.0)))
    ests = (4.0, 6.0)
    plan = _single_node_plan(node, ests, (1.0, 0.5), 100.0)
    blocks = _blocks(ests)
    act = ActuationModel(latency_s=1.0, switch_energy_j=0.5)
    rep = run_cluster(plan, blocks, config=RuntimeConfig(actuation=act))

    tab_t = block_time_table(blocks, node.ladder.states)
    tab_e = busy_energy_table(tab_t, np.ones(2), node.ladder.states,
                              node.power)
    starts = {e[3]: e[0] for e in rep.event_log if e[1] == "block_start"}
    finishes = {e[3]: e for e in rep.event_log if e[1] == "block_finish"}
    switch = next(e for e in rep.event_log if e[1] == "freq_switch")
    assert switch[3] == 1 and switch[4] == 1.0 and switch[5] == 0.5
    # segment 1: 1.0 s at f=1.0 -> work fraction done
    seg1 = switch[0] - starts[1]
    assert seg1 == pytest.approx(act.latency_s, abs=1e-12)
    w1 = seg1 / tab_t[1, 1]          # T(f=1.0) is state column 1
    # segment 2 duration from event times == remaining work at T(f=0.5)
    seg2 = finishes[1][0] - switch[0]
    assert seg2 == pytest.approx((1.0 - w1) * tab_t[1, 0], rel=1e-12)
    # reported busy/energy == segment sums off the tables
    busy = finishes[1][4]
    energy = finishes[1][5]
    assert busy == pytest.approx(w1 * tab_t[1, 1] + (1 - w1) * tab_t[1, 0],
                                 rel=1e-12)
    assert energy == pytest.approx(w1 * tab_e[1, 1] + (1 - w1) * tab_e[1, 0],
                                   rel=1e-12)
    # the transition itself was charged
    assert rep.n_switches == 1 and rep.switch_energy_j == 0.5


def test_midblock_fault_repricing_exact():
    """A time-based fault lands mid-block: the remaining work fraction is
    re-priced at the faulted speed, exactly."""
    node = NodeSpec("n0")
    ests = (5.0,)
    plan = _single_node_plan(node, ests, (1.0,), 100.0)
    rep = run_cluster(plan, _blocks(ests),
                      events=[FaultEvent(2.0, "n0", 3.0)])
    t_full = 5.0
    w_done = 2.0 / t_full
    expect = 2.0 + (1.0 - w_done) * (t_full * 3.0)
    nr = rep.node_reports[0]
    assert nr.busy_s == pytest.approx(expect, rel=1e-12)
    assert rep.makespan_s == pytest.approx(expect, rel=1e-12)


@settings(max_examples=15, deadline=None)
@given(
    costs=st.lists(st.floats(1.0, 12.0), min_size=3, max_size=12),
    latency=st.floats(0.1, 2.0),
    fault_t=st.floats(0.5, 10.0),
    factor=st.floats(1.2, 3.0),
)
def test_segment_sums_close_under_switches_and_faults(costs, latency,
                                                      fault_t, factor):
    """Property: however switches and faults slice the blocks, every block's
    reported busy time equals the sum of its segment durations measured
    from event timestamps (work is neither lost nor double-counted)."""
    blocks = _blocks(costs)
    nodes = _nodes(2)
    plan = plan_cluster(blocks, nodes, _deadline(blocks, nodes, 1.3))
    rep = run_cluster(
        plan, blocks,
        config=RuntimeConfig(online=True, ewma_alpha=0.6,
                             replan_threshold=0.05,
                             actuation=ActuationModel(latency_s=latency)),
        events=[SlowdownEvent("n0", 1, factor),
                FaultEvent(fault_t, "n1", factor)],
        est_blocks=blocks)
    bounds: dict = {}
    for e in rep.event_log:
        if e[1] == "block_start" and isinstance(e[3], (int, np.integer)):
            bounds[e[3]] = e[0]
        elif e[1] == "block_finish":
            start = bounds[e[3]]
            assert e[4] == pytest.approx(e[0] - start, rel=1e-9, abs=1e-9)


# --- (c) migration safety ----------------------------------------------------

def _migration_scenario(factor=4.0, n_blocks=24, slack=2.2):
    blocks = [BlockInfo(i, 5.0) for i in range(n_blocks)]
    nodes = [NodeSpec("n0", speed=1.0, ladder=DEEP),
             NodeSpec("n1", speed=0.8, ladder=DEEP),
             NodeSpec("n2", speed=1.25, ladder=DEEP)]
    deadline = max(sum(b.est_time_fmax for b in g) / n.speed
                   for g, n in zip(assign_blocks(blocks, nodes), nodes)) \
        * slack
    plan = plan_cluster(blocks, nodes, deadline, assignment="lpt")
    n0_blocks = len(plan.node_plans[0].blocks)
    events = [SlowdownEvent("n0", after_block=n0_blocks // 2 - 1,
                            factor=factor)]
    return plan, blocks, events, deadline


def test_migration_recovers_what_fmax_cannot():
    """Acceptance scenario: the static plan and the clock-up-only online run
    both miss; migration meets the deadline."""
    plan, blocks, events, _ = _migration_scenario()
    kw = dict(ewma_alpha=0.7, replan_threshold=0.1)
    r_static = run_cluster(plan, blocks, events=events)
    r_online = run_cluster(plan, blocks, events=events, est_blocks=blocks,
                           config=RuntimeConfig(online=True, **kw))
    r_mig = run_cluster(plan, blocks, events=events, est_blocks=blocks,
                        config=RuntimeConfig(online=True, migrate=True, **kw))
    assert not r_static.deadline_met
    assert not r_online.deadline_met
    assert r_mig.deadline_met
    assert r_mig.n_migrations >= 1


@settings(max_examples=12, deadline=None)
@given(
    factor=st.floats(2.5, 6.0),
    slack=st.floats(1.8, 2.6),
    n_blocks=st.integers(12, 30),
)
def test_migration_never_moves_inflight_or_breaks_feasible_nodes(
        factor, slack, n_blocks):
    plan, blocks, events, deadline = _migration_scenario(factor, n_blocks,
                                                         slack)
    rep = run_cluster(plan, blocks, events=events, est_blocks=blocks,
                      config=RuntimeConfig(online=True, migrate=True,
                                           ewma_alpha=0.7,
                                           replan_threshold=0.1))
    start_times: dict = {}
    for e in rep.event_log:
        if e[1] == "block_start" and isinstance(e[3], (int, np.integer)):
            start_times.setdefault(e[3], e[0])
    for mv in rep.migrations:
        # queued only: the block must not have started anywhere before the
        # move, and must start on the destination at or after it
        assert start_times[mv.block_index] >= mv.time - 1e-12
        # the guard held at decision time
        assert mv.dst_pred_s <= deadline + 1e-9
    # nodes that were not slowed ran exactly as predicted -> the guard
    # means they still finish inside the deadline even with migrated work
    for nr in rep.node_reports:
        if nr.name != "n0":
            assert nr.finish_s <= deadline + 1e-6


# --- (d) cluster power cap ---------------------------------------------------

def _reconstruct_peak(rep, blocks, nodes):
    """Independent power timeline from the event log (not the ledger)."""
    util = {b.index: b.util for b in blocks}
    spec = {n.name: n for n in nodes}
    draw = {n.name: n.power.p_idle for n in nodes}
    cur_block: dict = {}
    peak = sum(draw.values())
    for e in rep.event_log:
        name = e[2]
        if e[1] == "block_start" and isinstance(e[3], (int, np.integer)):
            cur_block[name] = e[3]
            draw[name] = spec[name].power.power(util[e[3]], e[4])
        elif e[1] == "block_finish":
            draw[name] = spec[name].power.p_idle
        elif e[1] == "freq_switch" and len(e) == 6 and e[4] != "idle":
            draw[name] = spec[name].power.power(util[cur_block[name]], e[5])
        peak = max(peak, sum(draw.values()))
    return peak


@settings(max_examples=12, deadline=None)
@given(
    costs=st.lists(st.floats(1.0, 10.0), min_size=4, max_size=24),
    slack=st.floats(1.1, 1.8),
    cap_frac=st.floats(0.7, 0.98),
    migrate=st.booleans(),
)
def test_power_cap_never_exceeded(costs, slack, cap_frac, migrate):
    blocks = _blocks(costs)
    nodes = _nodes(3)
    deadline = _deadline(blocks, nodes, slack)
    free = run_cluster(plan_cluster(blocks, nodes, deadline), blocks)
    idle_floor = sum(n.power.p_idle for n in nodes)
    cap = max(free.peak_power_w * cap_frac, idle_floor * 1.3,
              idle_floor + 140.0)
    plan = plan_cluster(blocks, nodes, deadline, power_cap_w=cap)
    cfg = RuntimeConfig(power_cap_w=cap, online=migrate, migrate=migrate,
                        ewma_alpha=0.7, replan_threshold=0.1)
    rep = run_cluster(plan, blocks, config=cfg,
                      events=[SlowdownEvent("n0", 1, 2.0)] if migrate else (),
                      est_blocks=blocks if migrate else None)
    assert rep.peak_power_w <= cap + 1e-9
    assert _reconstruct_peak(rep, blocks, nodes) <= cap + 1e-9


def test_power_cap_screen_downclocks_plan():
    """Plan-time screen: with slack available, the capped plan stays
    deadline-feasible but chooses lower peak power than the free plan."""
    blocks = _blocks([5.0] * 18)
    nodes = _nodes(3)
    deadline = _deadline(blocks, nodes, 1.6)
    free = plan_cluster(blocks, nodes, deadline)
    r_free = run_cluster(free, blocks)
    cap = r_free.peak_power_w * 0.9
    capped = plan_cluster(blocks, nodes, deadline, power_cap_w=cap)
    assert capped.feasible and capped.power_cap_ok
    r_cap = run_cluster(capped, blocks,
                        config=RuntimeConfig(power_cap_w=cap))
    assert r_cap.deadline_met
    assert r_cap.peak_power_w <= cap + 1e-9
    assert r_cap.peak_power_w < r_free.peak_power_w - 1e-6


def test_late_migration_respects_wall_clock_slack():
    """A target that drained long ago has busy-time 'slack' that is wall-
    clock stale: migrated work cannot start before NOW.  A late trigger
    (straggler detected near the deadline) must therefore move nothing
    instead of pushing the previously-feasible target past the deadline."""
    blocks = [BlockInfo(i, 3.8) for i in range(5)] + [BlockInfo(5, 1.0)]
    nodes = [NodeSpec("n0", ladder=DEEP), NodeSpec("n1", ladder=DEEP)]
    deadline = 20.0
    plan = plan_cluster(blocks, nodes, deadline,
                        assignment=[0, 0, 0, 0, 0, 1])
    rep = run_cluster(plan, blocks,
                      events=[SlowdownEvent("n0", 1, 4.0)],
                      est_blocks=blocks,
                      config=RuntimeConfig(online=True, migrate=True,
                                           ewma_alpha=0.7,
                                           replan_threshold=0.1))
    # n1 finished its 1 s block at t=1; the straggler is detected at t=19,
    # when n1's wall-clock room is one block at most — no 3.8 s block fits
    assert rep.n_migrations == 0
    n1 = next(nr for nr in rep.node_reports if nr.name == "n1")
    assert n1.finish_s <= deadline + 1e-9


def test_all_launches_deferred_is_not_a_met_deadline():
    """A cap above the idle floor but below any launchable draw defers every
    block forever; the empty run must NOT report deadline_met."""
    blocks = _blocks([2.0, 3.0])
    nodes = _nodes(2)   # idle floor 140 W; cheapest launch needs ~150.4 W
    plan = plan_cluster(blocks, nodes, 100.0)
    rep = run_cluster(plan, blocks, config=RuntimeConfig(power_cap_w=150.0))
    assert not rep.deadline_met
    assert all(nr.n_blocks == 0 for nr in rep.node_reports)


def test_power_cap_below_idle_floor_rejected():
    blocks = _blocks([1.0, 2.0])
    nodes = _nodes(2)
    plan = plan_cluster(blocks, nodes, 100.0)
    with pytest.raises(ValueError):
        run_cluster(plan, blocks,
                    config=RuntimeConfig(power_cap_w=1.0))


# --- (e) determinism ---------------------------------------------------------

def test_full_feature_run_is_deterministic():
    """Everything on at once (faults, migration, latency, cap): two runs
    produce identical event logs and identical reports."""
    plan, blocks, events, deadline = _migration_scenario()
    free = run_cluster(plan, blocks)
    cap = free.peak_power_w * 1.05   # head-room so migration stays possible
    cfg = RuntimeConfig(online=True, migrate=True, ewma_alpha=0.7,
                        replan_threshold=0.1, power_cap_w=cap,
                        actuation=ActuationModel(latency_s=0.5,
                                                 switch_energy_j=1.0))
    events = events + [FaultEvent(deadline * 0.6, "n1", 1.5)]
    r1 = run_cluster(plan, blocks, config=cfg, events=events,
                     est_blocks=blocks)
    r2 = run_cluster(plan, blocks, config=cfg, events=events,
                     est_blocks=blocks)
    assert r1.event_log == r2.event_log
    assert r1 == r2
    assert len(r1.event_log) > 0


def test_pipeline_stream_run_handoff():
    """Dataset -> plan -> runtime, SoA end to end: the streamed plan feeds
    the engine directly and executes drift-free against its own estimates
    (finish == prediction per node, deadline met on a feasible plan)."""
    from repro.pipeline import (PipelineConfig, stream_estimates, stream_run,
                                synthetic_cost_chunks)
    cfg = PipelineConfig()
    nodes = _nodes(3)
    est = stream_estimates(synthetic_cost_chunks(600, 32, seed=1), cfg)
    deadline = float(est.total.sum()) / (0.8 * len(nodes)) * 1.5
    rep = stream_run(est, deadline, cfg, nodes=nodes,
                     assignment="round_robin")
    assert rep.deadline_met
    assert sum(nr.n_blocks for nr in rep.node_reports) == 600
    # truth == estimates: execution realizes the plan's own predictions
    from repro.cluster import plan_cluster
    plan = plan_cluster(est.to_block_arrays(), nodes, deadline,
                        assignment="round_robin")
    for nr, npa in zip(rep.node_reports, plan.node_plans):
        assert nr.busy_s == pytest.approx(npa.pred_finish_s, rel=1e-12)


def test_runtime_config_validation():
    with pytest.raises(ValueError):
        RuntimeConfig(migrate=True)            # migration needs online
    with pytest.raises(ValueError):
        RuntimeConfig(power_cap_w=0.0)
    with pytest.raises(ValueError):
        ActuationModel(latency_s=-1.0)


def test_cost_model_validation_rejects_negative_energy():
    """Transfer/switch energies are joules: negative values would let a
    planner 'gain' energy by moving or switching."""
    from repro.runtime import MigrationModel
    with pytest.raises(ValueError):
        MigrationModel(energy_j_per_record=-0.01)
    with pytest.raises(ValueError):
        MigrationModel(latency_s_per_block=-1.0)
    with pytest.raises(ValueError):
        ActuationModel(switch_energy_j=-0.5)


# --- (f) power-ledger end-of-run invariant ----------------------------------

def _drained_ledger_ok(engine):
    """Every node back at p_idle, every aux (wire) watt released."""
    led = engine.ledger
    for nid in range(len(engine.nodes)):
        assert led.draw_of(nid) == led._idle[nid]
        assert abs(led.aux_of(nid)) < 1e-9
    assert led.total_w == pytest.approx(sum(led._idle), abs=1e-9)


def test_power_ledger_drains_to_idle_after_run():
    """End-of-run ledger invariant: when the queue empties, no node still
    'draws' busy watts and no migration wire is still charged — across the
    full feature matrix (faults, migration wire, cap, latency), crashes
    included, on both engines."""
    from repro.runtime import NodeFailureEvent, RecoveryPolicy
    from repro.runtime.engine import ClusterRuntime
    from repro.runtime.vector import VectorClusterRuntime
    plan, blocks, events, deadline = _migration_scenario()
    free = run_cluster(plan, blocks)
    cfg_kw = dict(online=True, migrate=True, ewma_alpha=0.7,
                  replan_threshold=0.1, power_cap_w=free.peak_power_w * 1.05,
                  actuation=ActuationModel(latency_s=0.5,
                                           switch_energy_j=1.0))
    from repro.runtime import MigrationModel
    cfg_kw["migration"] = MigrationModel(latency_s_per_block=1.0,
                                         energy_j_per_record=0.01)
    ev = events + [FaultEvent(deadline * 0.6, "n1", 1.5)]
    ev_crash = ev + [NodeFailureEvent(time=deadline * 0.4, node="n2",
                                      flavor="transient",
                                      repair_s=deadline * 0.1)]
    for cls in (ClusterRuntime, VectorClusterRuntime):
        for events_i, rec in ((ev, None), (ev_crash, RecoveryPolicy())):
            eng = cls(plan, blocks,
                      config=RuntimeConfig(**cfg_kw, recovery=rec),
                      events=events_i, est_blocks=blocks)
            eng.run()
            _drained_ledger_ok(eng)
