"""Vectorized event engine == frozen scalar oracle, property-based.

``repro.runtime.vector.VectorClusterRuntime`` batches same-timestamp
events and fast-forwards fault-free stretches with whole-array segment
arithmetic; ``repro.runtime.engine.ClusterRuntime`` stays the frozen
scalar oracle.  This suite is the contract that lets the oracle stay
frozen: across randomized fault / slowdown / actuation-latency /
power-cap / migration (with wire energy) / online-recalibration
scenarios the two engines must produce IDENTICAL reports and IDENTICAL
event logs — bitwise, not approximately.  Also pins two-run determinism
of the vectorized path and the zero-cost migration-energy regression.

Runs under the hypothesis compat shim, so the sweep executes
(fixed-seed) even where hypothesis is not installed.
"""
import dataclasses

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.cluster.node import NodeSpec
from repro.cluster.planner import plan_cluster
from repro.cluster.sim import SlowdownEvent
from repro.core.energy import FrequencyLadder, PowerModel
from repro.core.estimator import RooflineTerms, RooflineTimeModel
from repro.core.scheduler import BlockInfo
from repro.runtime import (ActuationModel, FaultEvent, MigrationModel,
                           RuntimeConfig, run_cluster)


def _scenario(seed):
    """Random plan + ground truth + runtime config, seeded.

    Covers the full feature matrix: rooflines on a subset of blocks,
    heterogeneous node speeds/power curves, tight and loose deadlines,
    faults, permanent slowdowns, actuation latency, switch energy,
    migration latency + wire energy, a cluster power cap, and online
    recalibration — each drawn independently so combinations land.
    """
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 250))
    blocks = []
    for i in range(n):
        est = float(rng.uniform(0.2, 3.0))
        roof = None
        if rng.random() < 0.4:
            roof = RooflineTimeModel(RooflineTerms(
                t_comp=est * float(rng.uniform(0.3, 0.8)),
                t_mem=est * float(rng.uniform(0.1, 0.5)),
                t_coll=est * float(rng.uniform(0, 0.2)),
                t_fixed=est * float(rng.uniform(0, 0.2))))
        blocks.append(BlockInfo(index=i, est_time_fmax=est,
                                est_rel_halfwidth=float(rng.uniform(0, 0.25)),
                                util=float(rng.uniform(0.4, 1.0)),
                                roofline=roof,
                                records=float(rng.integers(50, 4000))))
    k = int(rng.integers(2, 6))
    lows = sorted(rng.choice([0.4, 0.5, 0.6, 0.7, 0.8, 0.9], size=2,
                             replace=False))
    ladder = FrequencyLadder(tuple(float(v) for v in lows) + (1.0,))
    nodes = [NodeSpec(f"n{j}", ladder=ladder,
                      power=PowerModel(p_idle=30 + 3 * j, p_full=120 + 10 * j,
                                       alpha=float(rng.uniform(1.5, 3.0))),
                      speed=float(rng.uniform(0.7, 1.4)))
             for j in range(k)]
    tight = float(rng.uniform(0.6, 1.4))
    deadline = max(sum(b.est_time_fmax for b in blocks) / k * tight, 5.0)
    plan = plan_cluster(blocks, nodes, deadline_s=deadline)
    truth = [dataclasses.replace(b, est_time_fmax=b.est_time_fmax *
                                 float(rng.uniform(0.6, 2.0))) for b in blocks]
    events = []
    for _ in range(int(rng.integers(0, 5))):
        events.append(FaultEvent(time=float(rng.uniform(1, deadline)),
                                 node=f"n{int(rng.integers(0, k))}",
                                 factor=float(rng.uniform(1.05, 2.0))))
    for _ in range(int(rng.integers(0, 3))):
        events.append(SlowdownEvent(node=f"n{int(rng.integers(0, k))}",
                                    after_block=int(rng.integers(1, 30)),
                                    factor=float(rng.uniform(1.1, 1.8))))
    latency = float(rng.choice([0.0, 0.0, 0.3, 1.0]))
    idle_floor = sum(nd.power.p_idle for nd in nodes)
    cap = None
    if rng.random() < 0.6:
        cap = idle_floor + float(rng.uniform(0.3, 1.5)) * \
            sum(nd.power.p_full - nd.power.p_idle for nd in nodes) / k
    online = bool(rng.random() < 0.8)
    migrate = online and bool(rng.random() < 0.6)
    cfg = RuntimeConfig(
        online=online, migrate=migrate,
        actuation=ActuationModel(latency_s=latency,
                                 switch_energy_j=float(rng.choice([0.0, 0.25]))),
        migration=MigrationModel(
            latency_s_per_block=float(rng.choice([0.0, 1.0, 3.0])),
            energy_j_per_record=float(rng.choice([0.0, 0.005, 0.02]))),
        power_cap_w=cap, log_events=True)
    return plan, truth, cfg, events, blocks


def _run(engine, seed=None, parts=None):
    plan, truth, cfg, events, blocks = parts if parts else _scenario(seed)
    return run_cluster(plan, truth, config=cfg, events=events,
                       est_blocks=blocks, engine=engine)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_vector_engine_matches_scalar_oracle(seed):
    parts = _scenario(seed)
    a = _run("scalar", parts=parts)
    b = _run("vector", parts=parts)
    assert a == b
    assert a.event_log == b.event_log


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10**6))
def test_vector_engine_two_run_determinism(seed):
    parts = _scenario(seed)
    a = _run("vector", parts=parts)
    b = _run("vector", parts=parts)
    assert a == b
    assert a.event_log == b.event_log


def _everything_on_parts(seed=7):
    """A scenario with every subsystem forced on (not left to chance)."""
    plan, truth, cfg, events, blocks = _scenario(seed)
    if not events:
        events = [FaultEvent(time=2.0, node="n0", factor=1.5)]
    cap = cfg.power_cap_w
    if cap is None:
        cap = 1e9  # generous cap: exercises the cap machinery, binds never
    cfg = dataclasses.replace(
        cfg, online=True, migrate=True, power_cap_w=cap,
        actuation=ActuationModel(latency_s=0.3, switch_energy_j=0.25),
        migration=MigrationModel(latency_s_per_block=1.0,
                                 energy_j_per_record=0.005),
        log_events=True)
    return plan, truth, cfg, events, blocks


def test_everything_on_scalar_vector_identical():
    parts = _everything_on_parts()
    a = _run("scalar", parts=parts)
    b = _run("vector", parts=parts)
    assert a == b
    assert a.event_log == b.event_log


def test_auto_engine_selects_vector_result():
    parts = _scenario(3)
    assert _run("auto", parts=parts) == _run("vector", parts=parts)


def test_zero_cost_migration_model_is_bit_identical():
    """energy_j_per_record=0 must not perturb the simulation at all.

    Regression for the wire-energy accounting: a zero-cost migration
    model has to reproduce the pre-wire-energy trajectory bitwise (no
    spurious wire-release events, no energy drift), on both engines.
    """
    plan, truth, cfg, events, blocks = _everything_on_parts(seed=11)
    base = dataclasses.replace(
        cfg, migration=dataclasses.replace(cfg.migration,
                                           energy_j_per_record=0.0))
    legacy = dataclasses.replace(
        base, migration=MigrationModel(
            latency_s_per_block=base.migration.latency_s_per_block))
    for engine in ("scalar", "vector"):
        a = run_cluster(plan, truth, config=base, events=events,
                        est_blocks=blocks, engine=engine)
        b = run_cluster(plan, truth, config=legacy, events=events,
                        est_blocks=blocks, engine=engine)
        assert a == b
        assert a.event_log == b.event_log


def test_wire_energy_charged_per_record():
    """Wire joules = sum over moves of records * rate, kept out of busy energy."""
    plan, truth, cfg, events, blocks = _everything_on_parts(seed=11)
    rate = 0.05
    hot = dataclasses.replace(
        cfg, migration=dataclasses.replace(cfg.migration,
                                           energy_j_per_record=rate))
    cold = dataclasses.replace(
        cfg, migration=dataclasses.replace(cfg.migration,
                                           energy_j_per_record=0.0))
    a = _run("vector", parts=(plan, truth, hot, events, blocks))
    b = _run("vector", parts=(plan, truth, cold, events, blocks))
    assert b.migration_energy_j == 0.0
    expect = sum(mv.energy_j for mv in a.migrations)
    assert a.migration_energy_j == expect
    if a.migrations:
        assert a.migration_energy_j > 0.0
