"""Distribution layer: spec/tree structure match, divisibility, ZeRO-1,
int8 collective error bounds, shard_map grad reduce on a local mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_arch, smoke_config
from repro.launch import specs as S
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.parallel import (batch_specs, cache_specs, param_specs,
                            validate_divisibility, zero1_specs)
from repro.parallel.collectives import int8_all_reduce

MESH_SHAPE = {"data": 16, "model": 16}
MESH_SHAPE_MP = {"pod": 2, "data": 16, "model": 16}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_match_structure_and_divide(arch):
    cfg = get_arch(arch, tp=16)
    p_sds = S.params_shapes(cfg)
    spec = param_specs(cfg, p_sds, MESH_SHAPE)
    assert jax.tree_util.tree_structure(spec, is_leaf=lambda x: isinstance(x, P)) \
        .num_leaves == jax.tree_util.tree_structure(p_sds).num_leaves
    bad = validate_divisibility(spec, p_sds, MESH_SHAPE)
    assert not bad, bad


@pytest.mark.parametrize("arch", ["olmo-1b", "mixtral-8x7b", "jamba-1.5-large-398b"])
def test_zero1_adds_data_axis(arch):
    cfg = get_arch(arch, tp=16)
    p_sds = S.params_shapes(cfg)
    spec = param_specs(cfg, p_sds, MESH_SHAPE)
    zspec = zero1_specs(spec, p_sds, MESH_SHAPE)
    bad = validate_divisibility(zspec, p_sds, MESH_SHAPE)
    assert not bad, bad
    # at least the big matrices must now mention 'data'
    n_data = sum(1 for s in jax.tree.leaves(
        zspec, is_leaf=lambda x: isinstance(x, P))
        if any(ax is not None and "data" in ((ax,) if isinstance(ax, str)
                                             else ax) for ax in tuple(s)))
    assert n_data > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_match_structure(arch):
    from repro.configs.shapes import SHAPES
    cfg = get_arch(arch, tp=16)
    c_sds = S.cache_shapes(cfg, SHAPES["decode_32k"])
    spec = cache_specs(cfg, c_sds, MESH_SHAPE)
    bad = validate_divisibility(spec, c_sds, MESH_SHAPE)
    assert not bad, bad


def test_param_count_big_configs_fit_hbm():
    """bf16 params sharded per the specs must fit 16 GB/chip on the single pod."""
    for arch in ARCH_IDS:
        cfg = get_arch(arch, tp=16)
        p_sds = S.params_shapes(cfg)
        spec = param_specs(cfg, p_sds, MESH_SHAPE)

        def shard_bytes(leaf, s):
            n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
            for ax in tuple(s):
                if ax is None:
                    continue
                names = (ax,) if isinstance(ax, str) else ax
                for a in names:
                    n //= MESH_SHAPE[a]
            return n

        per_dev = sum(shard_bytes(l, s) for l, s in zip(
            jax.tree.leaves(p_sds),
            jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, P))))
        assert per_dev < 8e9, (arch, per_dev)  # leave room for opt + act


def test_int8_all_reduce_error_bound():
    mesh = jax.make_mesh((1,), ("pod",))
    from jax.experimental.shard_map import shard_map
    x = jnp.asarray(np.random.default_rng(0).normal(0, 3.0, (1000,)),
                    jnp.float32)

    f = shard_map(lambda t: int8_all_reduce(t, "pod"), mesh=mesh,
                  in_specs=P(), out_specs=P())
    out = f(x)
    err = np.abs(np.asarray(out) - np.asarray(x))
    scale = np.abs(np.asarray(x)).max()
    assert err.max() <= scale / 127.0 + 1e-6  # one quantization step


def test_batch_specs_divisibility_fallback():
    cfg = get_arch("olmo-1b", tp=16)
    b = {"tokens": jax.ShapeDtypeStruct((1, 128), jnp.int32)}  # B=1 < 16
    spec = batch_specs(cfg, b, MESH_SHAPE)
    assert tuple(spec["tokens"]) == ()  # replicated, not crashed
    b2 = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32)}
    spec2 = batch_specs(cfg, b2, MESH_SHAPE_MP)
    assert tuple(spec2["tokens"])[0] == ("pod", "data")
