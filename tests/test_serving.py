"""Open-loop serving fabric: admission, shedding, provisioning, identity.

The contract of ``repro.serving`` + ``repro.pipeline.arrivals``:

  (a) validation — malformed tenant/arrival/serving specs fail loudly at
      construction (negative rates, non-positive SLOs, priority ties,
      inverted hysteresis bands);
  (b) zero-traffic boundary — a serving run with no arrivals (and a run
      whose every job is rejected) is bitwise the closed-batch run on both
      engines; empty-tenant and empty-horizon replans never raise;
  (c) determinism + bit-identity — two runs of one spec produce identical
      ``ServingReport``s and event logs, and the vector engine matches the
      scalar oracle under arrivals, admission, shedding, and provisioning;
  (d) conservation — every arrived job is exactly-once accepted-and-
      finished, shed-and-reported, or rejected-and-reported (seeded
      overload campaign, zero violations);
  (e) policy — admission keeps accepted-job SLO misses rare where the
      no-admission baseline collapses, a 10x burst from one tenant is paid
      by that tenant (isolation), and elastic provisioning parks idle
      nodes / wakes them against backlog with priced wake transitions.
"""
import dataclasses

import numpy as np
import pytest

from repro.cluster.node import NodeSpec
from repro.cluster.planner import plan_cluster
from repro.core.energy import FrequencyLadder, PowerModel
from repro.core.scheduler import BlockInfo
from repro.pipeline import ArrivalSpec, TenantSpec, generate_arrivals
from repro.runtime import RuntimeConfig, run_cluster
from repro.serving import (ProvisioningPolicy, ServingConfig,
                           check_serving_conservation, run_serving,
                           run_serving_campaign, serving_scenario)

LADDER = FrequencyLadder((0.5, 0.7, 0.85, 1.0))
POWER = PowerModel(p_idle=30.0, p_full=110.0, alpha=2.0)


def _cluster(k=2, n_blocks=4, seed=3, slack=2.0):
    rng = np.random.default_rng(seed)
    blocks = [BlockInfo(index=i,
                        est_time_fmax=float(rng.uniform(0.3, 0.8)),
                        util=float(rng.uniform(0.5, 1.0)),
                        records=200.0)
              for i in range(n_blocks)]
    nodes = [NodeSpec(f"n{j}", ladder=LADDER, power=POWER, speed=1.0)
             for j in range(k)]
    deadline = sum(b.est_time_fmax for b in blocks) / k * slack
    plan = plan_cluster(blocks, nodes, deadline_s=deadline)
    truth = [dataclasses.replace(b, est_time_fmax=b.est_time_fmax * 1.05)
             for b in blocks]
    return plan, truth, blocks


def _config():
    return RuntimeConfig(online=True, log_events=True)


# --- (a) validation ---------------------------------------------------------

def test_tenant_spec_validation():
    ok = dict(name="t", rate_hz=1.0, slo_s=5.0)
    TenantSpec(**ok)
    with pytest.raises(ValueError, match="rate_hz"):
        TenantSpec(**{**ok, "rate_hz": -0.5})
    with pytest.raises(ValueError, match="slo_s"):
        TenantSpec(**{**ok, "slo_s": 0.0})
    with pytest.raises(ValueError, match="slo_s"):
        TenantSpec(**{**ok, "slo_s": -3.0})
    with pytest.raises(ValueError, match="priority"):
        TenantSpec(**{**ok, "priority": float("nan")})
    with pytest.raises(ValueError, match="process"):
        TenantSpec(**{**ok, "process": "fractal"})
    with pytest.raises(ValueError, match="blocks_per_job"):
        TenantSpec(**{**ok, "blocks_per_job": (0, 2)})
    with pytest.raises(ValueError, match="blocks_per_job"):
        TenantSpec(**{**ok, "blocks_per_job": (3, 2)})
    with pytest.raises(ValueError, match="block_time_s"):
        TenantSpec(**{**ok, "block_time_s": (0.0, 1.0)})
    with pytest.raises(ValueError, match="burst"):
        TenantSpec(**{**ok, "process": "burst", "burst_factor": 0.5})
    with pytest.raises(ValueError, match="burst window"):
        TenantSpec(**{**ok, "process": "burst", "burst_factor": 2.0,
                      "burst_start_s": 5.0, "burst_end_s": 1.0})
    with pytest.raises(ValueError, match="trace_times_s"):
        TenantSpec(**{**ok, "process": "trace",
                      "trace_times_s": (3.0, 1.0)})


def test_arrival_spec_validation():
    a = TenantSpec(name="a", rate_hz=1.0, slo_s=5.0, priority=1.0)
    b = TenantSpec(name="b", rate_hz=1.0, slo_s=5.0, priority=2.0)
    ArrivalSpec(tenants=(a, b), horizon_s=10.0)
    with pytest.raises(ValueError, match="at least one tenant"):
        ArrivalSpec(tenants=(), horizon_s=10.0)
    with pytest.raises(ValueError, match="duplicate tenant"):
        ArrivalSpec(tenants=(a, dataclasses.replace(a, priority=3.0)),
                    horizon_s=10.0)
    with pytest.raises(ValueError, match="priority tie"):
        ArrivalSpec(tenants=(a, dataclasses.replace(b, priority=1.0)),
                    horizon_s=10.0)
    with pytest.raises(ValueError, match="horizon_s"):
        ArrivalSpec(tenants=(a, b), horizon_s=0.0)


def test_serving_config_validation():
    ServingConfig()
    with pytest.raises(ValueError, match="margin"):
        ServingConfig(margin=1.0)
    with pytest.raises(ValueError, match="max_defers"):
        ServingConfig(max_defers=-1)
    with pytest.raises(ValueError, match="backoff_frac"):
        ServingConfig(backoff_frac=0.0)
    with pytest.raises(ValueError, match="quota_frac"):
        ServingConfig(quota_frac=0.0)
    with pytest.raises(ValueError, match="hysteresis"):
        ProvisioningPolicy(park_below=0.8, wake_above=0.5)
    with pytest.raises(ValueError, match="min_awake"):
        ProvisioningPolicy(min_awake=0)
    with pytest.raises(ValueError, match="wake latency"):
        ProvisioningPolicy(wake_latency_s=-1.0)


def test_run_serving_requires_online_and_log():
    plan, truth, blocks = _cluster()
    spec = ArrivalSpec(tenants=(TenantSpec(name="t", rate_hz=0.5,
                                           slo_s=6.0),),
                       horizon_s=4.0)
    with pytest.raises(ValueError, match="online"):
        run_serving(plan, truth, spec, config=RuntimeConfig(log_events=True))
    with pytest.raises(ValueError, match="log_events"):
        run_serving(plan, truth, spec,
                    config=RuntimeConfig(online=True, log_events=False))
    with pytest.raises(ValueError, match="engine"):
        run_serving(plan, truth, spec, config=_config(), engine="quantum")


# --- arrival generation -----------------------------------------------------

def test_generate_arrivals_deterministic_and_ordered():
    spec = ArrivalSpec(
        tenants=(TenantSpec(name="a", rate_hz=0.8, slo_s=5.0, priority=2.0),
                 TenantSpec(name="b", rate_hz=0.5, slo_s=8.0, priority=1.0,
                            process="burst", burst_factor=4.0,
                            burst_start_s=5.0, burst_end_s=10.0)),
        horizon_s=30.0, seed=11)
    one = generate_arrivals(spec)
    two = generate_arrivals(spec)
    assert one == two
    assert [j.job_id for j in one] == list(range(len(one)))
    keys = [(j.time, -j.priority, j.tenant) for j in one]
    assert keys == sorted(keys)
    for j in one:
        assert j.deadline_s > j.time and len(j.block_times) >= 1


def test_adding_a_tenant_never_perturbs_another():
    a = TenantSpec(name="a", rate_hz=0.7, slo_s=5.0, priority=2.0)
    b = TenantSpec(name="b", rate_hz=0.9, slo_s=4.0, priority=1.0)
    solo = generate_arrivals(ArrivalSpec(tenants=(a,), horizon_s=25.0,
                                         seed=3))
    both = generate_arrivals(ArrivalSpec(tenants=(a, b), horizon_s=25.0,
                                         seed=3))
    mine = [(j.time, j.block_times) for j in both if j.tenant == "a"]
    assert mine == [(j.time, j.block_times) for j in solo]


def test_trace_process_replays_times():
    tr = TenantSpec(name="t", rate_hz=0.0, slo_s=5.0, process="trace",
                    trace_times_s=(1.0, 2.5, 9.0, 99.0))
    jobs = generate_arrivals(ArrivalSpec(tenants=(tr,), horizon_s=10.0))
    assert [j.time for j in jobs] == [1.0, 2.5, 9.0]  # horizon clips


# --- (b) zero-traffic boundary ----------------------------------------------

@pytest.mark.parametrize("engine", ["scalar", "vector"])
def test_no_arrivals_is_bitwise_closed_batch(engine):
    plan, truth, blocks = _cluster()
    quiet = ArrivalSpec(tenants=(TenantSpec(name="t", rate_hz=0.0,
                                            slo_s=5.0),),
                        horizon_s=10.0)
    closed = run_cluster(plan, truth, config=_config(), est_blocks=blocks,
                         engine=engine)
    srep = run_serving(plan, truth, quiet, config=_config(),
                       est_blocks=blocks, engine=engine)
    assert srep.runtime == closed
    assert srep.event_log == closed.event_log
    assert srep.jobs == () and srep.n_accepted == 0


@pytest.mark.parametrize("engine", ["scalar", "vector"])
def test_all_rejected_is_closed_batch_plus_log_rows(engine):
    plan, truth, blocks = _cluster()
    # 5 s jobs against a 1 s SLO: nothing is ever feasible
    hopeless = ArrivalSpec(
        tenants=(TenantSpec(name="t", rate_hz=0.8, slo_s=1.0,
                            blocks_per_job=(1, 1),
                            block_time_s=(5.0, 5.0)),),
        horizon_s=5.0)
    closed = run_cluster(plan, truth, config=_config(), est_blocks=blocks,
                         engine=engine)
    srep = run_serving(plan, truth, hopeless, config=_config(),
                       serving=ServingConfig(max_defers=0),
                       est_blocks=blocks, engine=engine)
    assert srep.n_accepted == 0 and srep.n_shed == 0
    assert srep.n_rejected == len(srep.jobs) > 0
    kept = tuple(r for r in srep.event_log if r[1] != "job_arrival")
    assert kept == closed.event_log
    stripped = dataclasses.replace(srep.runtime, event_log=())
    assert stripped == dataclasses.replace(closed, event_log=())


def test_empty_horizon_and_empty_tenant_replans_do_not_raise():
    plan, truth, blocks = _cluster()
    tiny = ArrivalSpec(tenants=(TenantSpec(name="t", rate_hz=50.0,
                                           slo_s=4.0),),
                       horizon_s=1e-6)
    rep = run_serving(plan, truth, tiny, config=_config(),
                      est_blocks=blocks)
    assert check_serving_conservation(rep, plan) == []


# --- (c) determinism + scalar/vector identity --------------------------------

@pytest.mark.parametrize("seed", [1, 5, 17])
def test_two_run_determinism_and_vector_identity(seed):
    sc = serving_scenario(seed)

    def _one(engine):
        return run_serving(sc.plan, sc.truth, sc.arrivals,
                           config=sc.config(), serving=sc.serving,
                           arrival_truth=sc.arrival_truth, events=sc.events,
                           est_blocks=sc.blocks, engine=engine)

    a = _one("scalar")
    b = _one("scalar")
    v = _one("vector")
    assert a == b and a.event_log == b.event_log
    assert a == v and a.event_log == v.event_log


# --- (d) conservation -------------------------------------------------------

def test_serving_campaign_conserves():
    summary = run_serving_campaign(8, base_seed=100)
    assert summary["violations"] == []
    assert summary["n_jobs"] > 0 and summary["n_accepted"] > 0


# --- (e) policy behavior ----------------------------------------------------

def _overload_spec(burst=False):
    steady = TenantSpec(name="steady", rate_hz=0.25, slo_s=10.0,
                        priority=2.0, blocks_per_job=(1, 1),
                        block_time_s=(0.8, 1.2))
    if burst:
        noisy = TenantSpec(name="noisy", rate_hz=0.25, slo_s=6.0,
                           priority=1.0, blocks_per_job=(1, 1),
                           block_time_s=(0.8, 1.2), process="burst",
                           burst_factor=20.0, burst_start_s=8.0,
                           burst_end_s=14.0)
    else:
        noisy = TenantSpec(name="noisy", rate_hz=2.5, slo_s=10.0,
                           priority=1.0, blocks_per_job=(1, 1),
                           block_time_s=(0.8, 1.2))
    return ArrivalSpec(tenants=(steady, noisy), horizon_s=30.0, seed=2)


def test_admission_contains_overload_baseline_collapses():
    plan, truth, blocks = _cluster(k=2)
    spec = _overload_spec()
    guarded = run_serving(plan, truth, spec, config=_config(),
                          serving=ServingConfig(margin=0.15),
                          est_blocks=blocks)
    naked = run_serving(
        plan, truth, spec, config=_config(),
        serving=ServingConfig(admission=False, shedding=False),
        est_blocks=blocks)
    assert check_serving_conservation(guarded, plan) == []
    # 5x offered load: the baseline accepts everything and misses wholesale,
    # admission keeps every promise it makes
    assert naked.n_accepted == len(naked.jobs)
    assert naked.accepted_miss_rate > 0.3
    assert guarded.n_rejected + guarded.n_shed > 0
    assert guarded.accepted_miss_rate <= 0.01


def test_isolation_burst_tenant_pays_for_its_burst():
    plan, truth, blocks = _cluster(k=2)
    spec = _overload_spec(burst=True)
    rep = run_serving(plan, truth, spec, config=_config(),
                      serving=ServingConfig(margin=0.15),
                      est_blocks=blocks)
    assert check_serving_conservation(rep, plan) == []
    by = {t.tenant: t for t in rep.tenants}
    steady, noisy = by["steady"], by["noisy"]
    # the burster's 10x spike is paid in ITS rejects/sheds; the steady
    # tenant keeps its SLOs
    assert noisy.rejected + noisy.shed > 0
    assert steady.miss_rate <= 0.01
    assert steady.rejected + steady.shed <= max(1, steady.arrived // 4)


def test_provisioning_parks_idle_and_wakes_against_backlog():
    plan, truth, blocks = _cluster(k=3, n_blocks=3)
    # a thin trickle (parks the drained nodes), then a pile-up (wakes them)
    trickle = TenantSpec(name="t", rate_hz=0.0, slo_s=12.0, process="trace",
                         blocks_per_job=(1, 1), block_time_s=(1.0, 1.0),
                         trace_times_s=(2.0, 4.0, 6.0) + tuple(
                             10.0 + 0.05 * i for i in range(10)))
    spec = ArrivalSpec(tenants=(trickle,), horizon_s=30.0)
    pol = ProvisioningPolicy(wake_latency_s=0.2, wake_energy_j=5.0,
                             park_below=0.25, wake_above=0.75, window_s=4.0)
    cfg = ServingConfig(provisioning=pol)

    def _one(engine):
        return run_serving(plan, truth, spec, config=_config(), serving=cfg,
                           est_blocks=blocks, engine=engine)

    a = _one("scalar")
    v = _one("vector")
    assert a == v and a.event_log == v.event_log
    actions = [act for (_, _, act) in a.provisioning]
    assert "park" in actions and "wake" in actions
    n_wakes = actions.count("wake")
    assert a.wake_energy_j == pytest.approx(5.0 * n_wakes)
    assert any(s > 0 for _, s in a.parked_s)
    assert a.parked_saved_j > 0
    assert check_serving_conservation(a, plan) == []
