"""Checkpointing (atomic/async/torn-write), optimizer, fault-tolerant trainer,
straggler detector."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.configs import smoke_config
from repro.data import BlockDataset
from repro.models import transformer as T
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, linear_warmup_cosine)
from repro.train import StragglerDetector, TrainConfig, Trainer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(6, dtype=jnp.int32),
                  "d": (jnp.ones(3), jnp.zeros(2))}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    path = str(tmp_path / "ck")
    save_checkpoint(path, tree, step=7)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, step = load_checkpoint(path, like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_torn_write_skipped(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    tree = _tree()
    mgr.save(tree, 10)
    mgr.save(jax.tree.map(lambda x: x + 1, tree), 20)
    # corrupt the newest (simulate crash mid-write)
    meta = tmp_path / "step_0000000020" / "meta.json"
    meta.write_text(json.dumps({"complete": False}))
    restored, step = mgr.restore_latest(tree)
    assert step == 10  # fell back to the older valid one


def test_checkpoint_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(_tree(), s)
    assert mgr.steps() == [3, 4]


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, state = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert int(state["step"]) == 200


def test_clip_and_schedule():
    g = {"w": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    from repro.optim import global_norm
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    lr = linear_warmup_cosine(1e-3, 10, 100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-2)
    assert float(lr(jnp.int32(100))) < 5e-4


def _mk_trainer(tmp_path, **kw):
    cfg = smoke_config("olmo-1b")
    defaults = dict(batch=2, seq_len=64, total_steps=12, ckpt_every=4,
                    warmup=2, ckpt_dir=str(tmp_path / "ck"), seed=3,
                    dvfs_enabled=kw.pop("dvfs_enabled", False))
    defaults.update(kw)
    tc = TrainConfig(**defaults)
    ds = BlockDataset(n_blocks=4, records_per_block=64, max_len=48,
                      vocab=cfg.vocab, seed=1)
    return Trainer(cfg, tc, dataset=ds)


def test_trainer_loss_decreases(tmp_path):
    res = _mk_trainer(tmp_path, total_steps=25).run(resume=False)
    assert np.isfinite(res["final_loss"])
    assert res["final_loss"] < res["first_loss"]


def test_trainer_failure_recovery_is_bitexact(tmp_path):
    """Crash at step 9, restore from ckpt at 8 -> same params as a clean run."""
    clean = _mk_trainer(tmp_path / "a").run(resume=False)
    faulty = _mk_trainer(tmp_path / "b").run(resume=False, inject_failure_at=9)
    for a, b in zip(jax.tree.leaves(clean["params"]),
                    jax.tree.leaves(faulty["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_dvfs_saves_energy(tmp_path):
    res = _mk_trainer(tmp_path, dvfs_enabled=True, total_steps=16,
                      deadline_slack=1.3).run(resume=False)
    # the DVFS ledger uses simulated frequencies; busy energy must not exceed
    # the DVO (f_max) counterfactual
    assert res["energy"]["busy_j"] <= res["energy_dvo"]["busy_j"] * 1.001
    freqs = {h["rel_freq"] for h in res["history"]}
    assert any(f < 1.0 for f in freqs)  # it actually down-clocked something


def test_straggler_detector():
    det = StragglerDetector(warmup_steps=3)
    flags = [det.observe(i, 1.0 + 0.01 * (i % 3)) for i in range(10)]
    assert not any(flags)
    assert det.observe(10, 5.0)          # 5x outlier flagged
    assert det.events and det.events[0]["step"] == 10
    # late-vs-budget path
    det2 = StragglerDetector(warmup_steps=0, budget_factor=1.5)
    for i in range(3):
        det2.observe(i, 1.0, planned_slot_s=1.0)
    assert det2.observe(3, 1.6, planned_slot_s=1.0)
