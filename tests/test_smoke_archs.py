"""Per-architecture smoke tests: reduced same-family config, one forward/train
step on CPU, asserting output shapes + no NaNs; decode-vs-prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, smoke_config
from repro.models import transformer as T

B, S = 2, 64


def make_batch(cfg, rng, batch=B, seq=S):
    b = {}
    shape = (batch, seq, cfg.n_codebooks) if cfg.n_codebooks else (batch, seq)
    b["tokens"] = jnp.asarray(rng.integers(1, cfg.vocab, shape), jnp.int32)
    b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, shape), jnp.int32)
    if cfg.frontend == "patch":
        b["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.n_patches, cfg.patch_dim)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, np.random.default_rng(0))

    def loss(p, b):
        return T.loss_fn(p, cfg, b)

    (val, metrics), grads = jax.jit(jax.value_and_grad(loss, has_aux=True))(
        params, batch)
    assert np.isfinite(float(val)), f"{arch}: non-finite loss"
    assert float(val) > 0
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.all(np.isfinite(np.asarray(g))) for g in leaves), \
        f"{arch}: non-finite grads"
    # output shape checks via forward
    hidden, aux = jax.jit(lambda p, b: T.forward(p, cfg, b))(params, batch)
    s_total = S + (cfg.n_patches if cfg.frontend == "patch" else 0)
    assert hidden.shape == (B, s_total, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(hidden)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = make_batch(cfg, rng)
    total = S + (cfg.n_patches if cfg.frontend == "patch" else 0)

    lp, cache = jax.jit(lambda p, b: T.prefill(p, cfg, b, total + 4))(params, batch)
    nxt = batch["tokens"][:, -1:]
    ld, cache2 = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c))(
        params, nxt, cache)
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    b2.pop("labels", None)
    lp2, _ = jax.jit(lambda p, b: T.prefill(p, cfg, b, total + 8))(params, b2)

    tol = 2e-2 if cfg.kv_quant else 1e-4   # int8 KV quantization error budget
    err = float(jnp.max(jnp.abs(lp2 - ld)))
    assert err < tol, f"{arch}: decode/prefill mismatch {err}"
    assert int(cache2["pos"]) == total + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_estimate(arch):
    cfg = smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    actual = sum(x.size for x in jax.tree.leaves(params))
    est = cfg.param_count()
    # estimate ignores norms/biases/frontends — allow 20%
    assert abs(actual - est) / actual < 0.2, (actual, est)
