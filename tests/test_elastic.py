"""Elastic restart: checkpoints are topology-independent — written under one
mesh, restored onto another (different device count / sharding).

Subprocess-based: each phase runs with its own
--xla_force_host_platform_device_count (jax locks device count at init).
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(n_devices: int, body: str) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={n_devices}"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, load_checkpoint
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_checkpoint_restores_across_meshes(tmp_path):
    path = str(tmp_path / "ck")
    # phase 1: write under a (4, 'data') mesh with sharded params
    _run(4, f"""
        mesh = jax.make_mesh((4,), ("data",))
        w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        w = jax.device_put(w, NamedSharding(mesh, P("data", None)))
        save_checkpoint({path!r}, {{"w": w, "step_arr": jnp.int32(3)}}, step=3)
        print("saved", w.sharding)
    """)
    # phase 2: restore under a DIFFERENT mesh (8 devices, model axis)
    out = _run(8, f"""
        mesh = jax.make_mesh((8,), ("model",))
        like = {{"w": jnp.zeros((8, 8), jnp.float32),
                 "step_arr": jnp.int32(0)}}
        sh = {{"w": NamedSharding(mesh, P(None, "model")),
              "step_arr": NamedSharding(mesh, P())}}
        tree, step = load_checkpoint({path!r}, like, shardings=sh)
        assert step == 3
        assert np.allclose(np.asarray(tree["w"]),
                           np.arange(64).reshape(8, 8))
        print("restored-on", len(jax.devices()), "devices",
              tree["w"].sharding.spec)
    """)
    assert "restored-on 8 devices" in out


def test_trainer_state_elastic(tmp_path):
    """Trainer checkpoints written single-device restore under a 4-dev mesh."""
    path = str(tmp_path / "ck")
    _run(1, f"""
        from repro.configs import smoke_config
        from repro.models import transformer as T
        from repro.optim import AdamWConfig, adamw_init
        cfg = smoke_config("olmo-1b")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params, AdamWConfig())
        save_checkpoint({path!r}, {{"params": params, "opt": opt}}, step=7)
        print("saved")
    """)
    out = _run(4, f"""
        from repro.configs import smoke_config
        from repro.models import transformer as T
        from repro.optim import AdamWConfig, adamw_init
        cfg = smoke_config("olmo-1b")
        params = T.init_params(cfg, jax.random.PRNGKey(1))  # different init
        opt = adamw_init(params, AdamWConfig())
        tree, step = load_checkpoint({path!r},
                                     {{"params": params, "opt": opt}})
        assert step == 7
        # restored params differ from the local init (they come from disk)
        a = jax.tree.leaves(tree["params"])[0]
        b = jax.tree.leaves(params)[0]
        assert not np.allclose(np.asarray(a), np.asarray(b))
        print("elastic-restore-ok")
    """)
    assert "elastic-restore-ok" in out


def test_hierarchical_grad_reduce_multipod():
    """int8 cross-pod + fp intra-pod reduction on a (pod=2, data=2) mesh."""
    out = _run(4, """
        from jax.experimental.shard_map import shard_map
        from repro.parallel.collectives import hierarchical_grad_reduce
        mesh = jax.make_mesh((2, 2), ("pod", "data"))
        def f(g):
            return hierarchical_grad_reduce({"w": g}, mesh)["w"]
        fm = shard_map(f, mesh=mesh, in_specs=P("pod", "data"),
                       out_specs=P("pod", "data"))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(0, 1, (8, 8)), jnp.float32)
        out = fm(g)
        # mean over the 4 DP shards of the per-shard rows
        ref = np.asarray(g).reshape(2, 4, 2, 4)
        ref = ref.mean(axis=(0, 2), keepdims=True)
        ref = np.broadcast_to(ref, (2, 4, 2, 4)).reshape(8, 8)
        err = np.abs(np.asarray(out) - ref).max()
        scale = np.abs(ref).max()
        assert err <= scale / 64, (err, scale)   # int8 cross-pod tolerance
        print("hier-reduce-ok", float(err))
    """)
    assert "hier-reduce-ok" in out
