"""Serving engine: generation correctness + DV-DVFS window accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import RooflineTimeModel
from repro.models import transformer as T
from repro.serve import ServeConfig, ServingEngine


def _engine(planner="roofline", window=8, mem_bound=True, **sc_kw):
    cfg = smoke_config("olmo-1b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rt = RooflineTimeModel.from_counts(
        flops=1e9, hbm_bytes=8e9 if mem_bound else 1e6, coll_bytes=0)
    sc_kw.setdefault("slack", 1.15)
    eng = ServingEngine(cfg, params,
                        ServeConfig(batch=2, max_len=128, window=window,
                                    planner=planner, **sc_kw),
                        roofline=rt)
    prompts = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(1, cfg.vocab, (2, 16)), jnp.int32)}
    return eng, prompts


def test_generate_shapes_and_determinism():
    eng, prompts = _engine()
    out = eng.generate(prompts, n_tokens=24)
    assert out["tokens"].shape[0] == 2
    assert out["n_generated"] >= 24
    # greedy decoding from the same params/prompts is deterministic
    eng2, prompts2 = _engine()
    out2 = eng2.generate(prompts2, n_tokens=24)
    np.testing.assert_array_equal(np.asarray(out["tokens"]),
                                  np.asarray(out2["tokens"]))


def test_memory_bound_decode_gets_free_downclock():
    """Roofline planner on a memory-bound decode: energy drops, clocks < 1."""
    eng, prompts = _engine(mem_bound=True)
    out = eng.generate(prompts, n_tokens=32)
    assert out["energy"]["busy_j"] < out["energy_dvo"]["busy_j"]
    assert any(f < 1.0 for f in eng.actuator.history)


def test_compute_bound_decode_stays_fast():
    """Compute-bound roofline + tight slack: little room to down-clock."""
    eng, prompts = _engine(mem_bound=False)
    out = eng.generate(prompts, n_tokens=32)
    # still never worse than DVO
    assert out["energy"]["busy_j"] <= out["energy_dvo"]["busy_j"] * 1.01


def test_short_generation_no_windows():
    """All tokens inside the calibration window: ledgers match DVO exactly."""
    eng, prompts = _engine(window=16)
    out = eng.generate(prompts, n_tokens=8)
    assert out["energy"]["busy_j"] == out["energy_dvo"]["busy_j"]


def test_multi_replica_decode_windows():
    """3 heterogeneous replicas under a shared SLO: the cluster planner pins
    windows to their replica, slow hosts clock higher than fast ones, and
    the aggregate still beats DVO.  Tokens are unchanged vs single-replica
    (replica 0 decodes physically either way)."""
    eng, prompts = _engine(replicas=3, replica_speeds=(1.0, 0.8, 1.25),
                           slack=1.4)
    out = eng.generate(prompts, n_tokens=32)
    single, prompts1 = _engine(slack=1.4)
    out1 = single.generate(prompts1, n_tokens=32)
    np.testing.assert_array_equal(np.asarray(out["tokens"]),
                                  np.asarray(out1["tokens"]))

    cp = eng.cluster_plan
    assert cp is not None and cp.feasible
    # every window is pinned to its own replica
    n_windows = len(cp.node_plans[0].blocks)
    for r, np_ in enumerate(cp.node_plans):
        assert len(np_.blocks) == n_windows
        assert all(r * n_windows <= bp.index < (r + 1) * n_windows
                   for bp in np_.blocks)
    # slowest host needs the highest clocks (same work, same deadline)
    mean_freq = [np.mean([bp.rel_freq for bp in p.blocks])
                 for p in cp.node_plans]
    assert mean_freq[1] >= mean_freq[2]
    # aggregate across replicas still saves energy vs all-f_max
    assert out["energy"]["busy_j"] <= out["energy_dvo"]["busy_j"] * 1.01
    assert out["energy"]["steps"] > out1["energy"]["steps"]
