# Tests run on the single real CPU device (the 512-device XLA_FLAGS override is
# set ONLY inside launch/dryrun.py, never globally).
import os
import sys

# keep test determinism and avoid accidental flag leakage from the environment
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
