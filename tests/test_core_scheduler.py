"""DV-DVFS scheduler invariants — unit + hypothesis property tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (DEFAULT_LADDER, TPU_V5E_POWER, BlockInfo,
                        FrequencyLadder, PowerModel, RooflineTimeModel,
                        plan_dvfs, plan_dvo, simulate, zipf_block_sizes)


def _blocks(costs):
    return [BlockInfo(i, float(c)) for i, c in enumerate(costs)]


def test_dvo_is_identity_speed():
    blocks = _blocks([1.0, 2.0, 3.0])
    rep = simulate(plan_dvo(blocks, 10.0), blocks)
    assert rep.total_time_s == pytest.approx(6.0)
    assert rep.deadline_met


def test_paper_planner_meets_deadline_and_saves_energy():
    sizes = zipf_block_sizes(16, 10000, z=1.0, seed=0)
    costs = sizes / sizes.mean() * 5.0
    blocks = _blocks(costs)
    deadline = float(costs.sum() * 1.2)
    plan = plan_dvfs(blocks, deadline, planner="paper")
    rep = simulate(plan, blocks)
    dvo = simulate(plan_dvo(blocks, deadline), blocks)
    assert plan.feasible and rep.deadline_met
    assert rep.total_energy_j < dvo.total_energy_j
    assert rep.total_time_s >= dvo.total_time_s  # paper trades time for energy


def test_global_planner_dominates_paper():
    """The offline greedy must save at least as much energy as equal slots."""
    rng = np.random.default_rng(0)
    costs = rng.lognormal(1.0, 0.8, 24)
    blocks = _blocks(costs)
    deadline = float(costs.sum()) * 1.15
    rep_p = simulate(plan_dvfs(blocks, deadline, planner="paper"), blocks)
    rep_g = simulate(plan_dvfs(blocks, deadline, planner="global"), blocks)
    assert rep_g.deadline_met
    assert rep_g.total_energy_j <= rep_p.total_energy_j * 1.001


def test_roofline_free_downclock():
    """Memory-bound blocks save energy with zero time increase."""
    rt = RooflineTimeModel.from_counts(flops=1e12, hbm_bytes=20e9,
                                       coll_bytes=0, chips=1)
    assert rt.zero_cost_freq() < 0.5
    blocks = [BlockInfo(i, rt.time_at(1.0), roofline=rt) for i in range(8)]
    deadline = sum(b.est_time_fmax for b in blocks) * 1.0001  # NO slack
    plan = plan_dvfs(blocks, deadline, planner="roofline", error_margin=0.0)
    rep = simulate(plan, blocks)
    dvo = simulate(plan_dvo(blocks, deadline), blocks)
    assert rep.deadline_met
    assert rep.total_time_s == pytest.approx(dvo.total_time_s, rel=1e-6)
    assert rep.total_energy_j < dvo.total_energy_j * 0.8


@settings(max_examples=50, deadline=None)
@given(
    costs=st.lists(st.floats(0.1, 50.0), min_size=1, max_size=40),
    slack=st.floats(0.0, 1.0),
    planner=st.sampled_from(["paper", "global"]),
)
def test_property_deadline_and_ladder(costs, slack, planner):
    """For ANY block mix and any deadline >= DVO time: deadline met, frequencies
    from the ladder, energy never above DVO."""
    blocks = _blocks(costs)
    deadline = sum(costs) * (1.0 + slack) + 1e-6
    plan = plan_dvfs(blocks, deadline, planner=planner)
    rep = simulate(plan, blocks)
    assert plan.feasible
    assert rep.deadline_met
    for bp in plan.blocks:
        assert any(abs(bp.rel_freq - f) < 1e-9 for f in DEFAULT_LADDER.states)
    dvo = simulate(plan_dvo(blocks, deadline), blocks)
    assert rep.total_energy_j <= dvo.total_energy_j * (1 + 1e-9)


@settings(max_examples=30, deadline=None)
@given(st.floats(1.05, 2.0), st.floats(0.0, 0.4))
def test_property_firm_beats_tight(firm_slack, tighten):
    """Paper Fig. 13: a firmer deadline never saves LESS energy."""
    rng = np.random.default_rng(7)
    costs = rng.lognormal(1.0, 0.7, 16)
    blocks = _blocks(costs)
    total = float(costs.sum())
    tight = total * max(1.0 + 1e-9, firm_slack - tighten)
    firm = total * firm_slack
    e_tight = simulate(plan_dvfs(blocks, tight, planner="global"), blocks)
    e_firm = simulate(plan_dvfs(blocks, firm, planner="global"), blocks)
    assert e_firm.total_energy_j <= e_tight.total_energy_j * (1 + 1e-9)


def test_power_model_monotonic():
    pm = PowerModel()
    freqs = np.linspace(0.5, 1.0, 11)
    powers = [pm.power(1.0, f) for f in freqs]
    assert all(b > a for a, b in zip(powers, powers[1:]))
    assert pm.power(0.0, 1.0) == pytest.approx(pm.p_idle)
    # paper formula (3): full-util busy power == p_full
    assert pm.paper_block_power(1.0, 1.0) == pytest.approx(pm.p_full)


def test_ladder_validation():
    with pytest.raises(ValueError):
        FrequencyLadder(states=(0.5, 0.9))     # must end at 1.0
    with pytest.raises(ValueError):
        FrequencyLadder(states=(0.9, 0.5, 1.0))  # ascending
    lad = FrequencyLadder(states=(0.5, 0.75, 1.0))
    assert lad.lowest_feasible(0.6) == 0.75
    assert lad.lowest_feasible(0.2) == 0.5
    assert lad.floor_state(0.8) == 0.75


def test_bucketed_scan_feasible_and_within_energy_bound():
    """``exact=False`` (bucketed-key sorted scan): still deterministic and
    deadline-feasible, energy within 2% of the exact greedy — and inert in
    the ample-budget regime where the all-fits fast path resolves."""
    from repro.core.scheduler import plan_dvfs_arrays
    from repro.core.soa import BlockArrays

    rng = np.random.default_rng(12)
    ba = BlockArrays.build(rng.lognormal(0.0, 0.8, 2000),
                           est_rel_halfwidth=rng.uniform(0, 0.2, 2000),
                           util=rng.uniform(0.4, 1.0, 2000))
    total = float(ba.est_time_fmax.sum())
    for slack in (1.03, 1.1, 1.3):
        dl = total * slack
        exact = plan_dvfs_arrays(ba, dl, planner="global")
        fast = plan_dvfs_arrays(ba, dl, planner="global", exact=False)
        again = plan_dvfs_arrays(ba, dl, planner="global", exact=False)
        assert np.array_equal(fast.rel_freq, again.rel_freq)
        assert fast.feasible
        assert float(fast.pred_time_s.sum()) <= dl + 1e-9
        e_exact = float(exact.pred_energy_j.sum())
        e_fast = float(fast.pred_energy_j.sum())
        assert e_fast <= e_exact * 1.02 + 1e-9
    # ample budget: every chain fits, both modes take the all-fits path
    ample = plan_dvfs_arrays(ba, total * 4.0, planner="global", exact=False)
    ref = plan_dvfs_arrays(ba, total * 4.0, planner="global")
    assert np.array_equal(ample.rel_freq, ref.rel_freq)
